//! Span timing: named phases, RAII scope timers, and the process-global
//! trace sink.
//!
//! The design goal is *compiled-in but free when off*: instrumentation
//! lives permanently in every engine's hot loop, and the disabled fast
//! path is exactly one relaxed atomic load per span ([`enabled`]) — no
//! clock read, no thread-local access, no allocation. The `obs_gate`
//! bench row enforces this (≤1% overhead with tracing *enabled* on the
//! tracked derivative workload; spans only wrap sweep/pass-granularity
//! work, never per-coordinate steps).
//!
//! When enabled, each [`SpanTimer`] records into a static per-phase slot
//! of relaxed atomics: invocation count, total wall nanoseconds, *self*
//! nanoseconds (total minus time spent in same-thread child spans — the
//! quantity a profile sorts by), and a log₂ duration histogram shared
//! with the serving metrics ([`super::hist`]). Self-time bookkeeping
//! uses a thread-local running child-time cell, so spans recorded on
//! shard worker threads never subtract from the coordinator's phases;
//! such phases are flagged [`Phase::is_parallel`] and excluded from the
//! wall-clock reconciliation the `profile` subcommand prints.
//!
//! Determinism invariant: tracing touches clocks and counters only —
//! never the optimizer's floating-point stream. A traced fit is bitwise
//! identical to an untraced one (`tests/obs.rs` enforces this across
//! thread counts).

use super::hist::LatencyHistogram;
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Instant;

/// Every named phase the engines record. The set is closed on purpose:
/// a fixed enum indexes a static stats array, so recording needs no map
/// lookup and no locking.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Root span a CLI command opens around its whole run; its total is
    /// the wall clock the profile reconciles against.
    Fit,
    /// `Workspace::prepare` — the risk-set prefix-sum rebuild.
    WorkspacePrepare,
    /// One batched all-coordinate d1/d2 derivative pass.
    DerivativePass,
    /// One full coordinate-descent sweep of the in-memory engine.
    CdSweep,
    /// Strong-rule screening (candidate-set construction) per λ point.
    PathScreen,
    /// KKT repair rounds per λ point (re-sweeps after violations).
    PathKktRepair,
    /// Sampled-block warmup phase of the streaming fit.
    StreamWarmup,
    /// One exact chunked-CD sweep of the streaming fit.
    StreamExactSweep,
    /// Shard-worker Scan leg (per-coordinate derivative scan).
    ShardScan,
    /// Shard-worker Emit leg (carry emission for the merge tiles).
    ShardEmit,
    /// Shard-worker Apply leg (coordinate delta application).
    ShardApply,
    /// Segment-block warmup passes of the incremental live refit.
    RefitWarmup,
    /// Exact chunked-CD polish of the incremental live refit.
    RefitExact,
}

/// Number of phases (the static stats table's length).
pub const N_PHASES: usize = 13;

impl Phase {
    /// All phases, in stats-table order.
    pub const ALL: [Phase; N_PHASES] = [
        Phase::Fit,
        Phase::WorkspacePrepare,
        Phase::DerivativePass,
        Phase::CdSweep,
        Phase::PathScreen,
        Phase::PathKktRepair,
        Phase::StreamWarmup,
        Phase::StreamExactSweep,
        Phase::ShardScan,
        Phase::ShardEmit,
        Phase::ShardApply,
        Phase::RefitWarmup,
        Phase::RefitExact,
    ];

    fn index(self) -> usize {
        match self {
            Phase::Fit => 0,
            Phase::WorkspacePrepare => 1,
            Phase::DerivativePass => 2,
            Phase::CdSweep => 3,
            Phase::PathScreen => 4,
            Phase::PathKktRepair => 5,
            Phase::StreamWarmup => 6,
            Phase::StreamExactSweep => 7,
            Phase::ShardScan => 8,
            Phase::ShardEmit => 9,
            Phase::ShardApply => 10,
            Phase::RefitWarmup => 11,
            Phase::RefitExact => 12,
        }
    }

    /// Stable snake_case name used in trace files and reports.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Fit => "fit",
            Phase::WorkspacePrepare => "workspace_prepare",
            Phase::DerivativePass => "derivative_pass",
            Phase::CdSweep => "cd_sweep",
            Phase::PathScreen => "path_screen",
            Phase::PathKktRepair => "path_kkt_repair",
            Phase::StreamWarmup => "stream_warmup",
            Phase::StreamExactSweep => "stream_exact_sweep",
            Phase::ShardScan => "shard_scan",
            Phase::ShardEmit => "shard_emit",
            Phase::ShardApply => "shard_apply",
            Phase::RefitWarmup => "refit_warmup",
            Phase::RefitExact => "refit_exact",
        }
    }

    /// Inverse of [`Phase::name`] (trace-file parsing).
    pub fn from_name(name: &str) -> Option<Phase> {
        Phase::ALL.iter().copied().find(|p| p.name() == name)
    }

    /// Phases recorded on worker threads *concurrently* with the
    /// coordinator. Their self-time is thread-time, not wall time, so
    /// the profile's wall-clock reconciliation excludes them and lists
    /// them separately.
    pub fn is_parallel(self) -> bool {
        matches!(self, Phase::ShardScan | Phase::ShardEmit | Phase::ShardApply)
    }
}

/// One phase's accumulated stats — all relaxed atomics, recorded
/// lock-free from any thread.
struct PhaseStat {
    count: AtomicU64,
    total_ns: AtomicU64,
    self_ns: AtomicU64,
    hist: LatencyHistogram,
}

impl PhaseStat {
    const fn new() -> Self {
        PhaseStat {
            count: AtomicU64::new(0),
            total_ns: AtomicU64::new(0),
            self_ns: AtomicU64::new(0),
            hist: LatencyHistogram::new(),
        }
    }
}

/// The one global on/off switch — the only thing a disabled span loads.
static ENABLED: AtomicBool = AtomicBool::new(false);

const PHASE_STAT_INIT: PhaseStat = PhaseStat::new();
static STATS: [PhaseStat; N_PHASES] = [PHASE_STAT_INIT; N_PHASES];

thread_local! {
    /// Nanoseconds consumed by already-closed child spans of the
    /// innermost open span *on this thread* — what a closing span
    /// subtracts from its total to get self-time.
    static CHILD_NS: Cell<u64> = const { Cell::new(0) };
}

/// Is span recording on? One relaxed load; inlined into every span and
/// counter site.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span/counter recording on or off (process-global).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Zero every phase stat and engine counter (training gauges persist —
/// they are serving-side gauges, not per-run trace state).
pub fn reset() {
    for s in &STATS {
        s.count.store(0, Ordering::Relaxed);
        s.total_ns.store(0, Ordering::Relaxed);
        s.self_ns.store(0, Ordering::Relaxed);
        s.hist.reset();
    }
    super::counters::reset_counters();
}

struct ActiveSpan {
    phase: Phase,
    start: Instant,
    /// The outer span's accumulated child time, restored (plus this
    /// span's total) when this span closes.
    outer_child_ns: u64,
}

/// RAII scope timer: construct at phase entry, record on drop. When
/// recording is disabled the constructor returns an inert timer after a
/// single atomic load.
pub struct SpanTimer(Option<ActiveSpan>);

impl SpanTimer {
    #[inline]
    pub fn start(phase: Phase) -> SpanTimer {
        if !enabled() {
            return SpanTimer(None);
        }
        let outer_child_ns = CHILD_NS.with(|c| {
            let v = c.get();
            c.set(0);
            v
        });
        SpanTimer(Some(ActiveSpan { phase, start: Instant::now(), outer_child_ns }))
    }
}

impl Drop for SpanTimer {
    fn drop(&mut self) {
        let Some(span) = self.0.take() else { return };
        let total_ns = span.start.elapsed().as_nanos() as u64;
        let child_ns = CHILD_NS.with(|c| {
            let own_children = c.get();
            // This whole span is a child of whatever encloses it.
            c.set(span.outer_child_ns.saturating_add(total_ns));
            own_children
        });
        let stat = &STATS[span.phase.index()];
        stat.count.fetch_add(1, Ordering::Relaxed);
        stat.total_ns.fetch_add(total_ns, Ordering::Relaxed);
        stat.self_ns.fetch_add(total_ns.saturating_sub(child_ns), Ordering::Relaxed);
        stat.hist.record(total_ns / 1_000);
    }
}

/// A read-only copy of one phase's stats.
#[derive(Clone, Debug)]
pub struct PhaseSnapshot {
    pub phase: Phase,
    pub count: u64,
    pub total_ns: u64,
    pub self_ns: u64,
    pub buckets: [u64; super::hist::N_BUCKETS],
}

/// Snapshot every phase (including zero-count ones, so two snapshots
/// can be diffed index-for-index).
pub fn snapshot_phases() -> Vec<PhaseSnapshot> {
    Phase::ALL
        .iter()
        .map(|&phase| {
            let s = &STATS[phase.index()];
            PhaseSnapshot {
                phase,
                count: s.count.load(Ordering::Relaxed),
                total_ns: s.total_ns.load(Ordering::Relaxed),
                self_ns: s.self_ns.load(Ordering::Relaxed),
                buckets: s.hist.bucket_counts(),
            }
        })
        .collect()
}

#[cfg(test)]
pub(crate) mod test_support {
    use std::sync::{Mutex, MutexGuard};

    /// Serializes tests that flip the global [`super::enabled`] switch
    /// or read/reset the global stats, across all obs test modules.
    static LOCK: Mutex<()> = Mutex::new(());

    pub fn obs_test_guard() -> MutexGuard<'static, ()> {
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::obs_test_guard;
    use super::*;

    #[test]
    fn phase_names_round_trip_and_index_the_table() {
        for (i, p) in Phase::ALL.iter().enumerate() {
            assert_eq!(p.index(), i);
            assert_eq!(Phase::from_name(p.name()), Some(*p));
        }
        assert_eq!(Phase::from_name("nope"), None);
        assert!(Phase::ShardScan.is_parallel() && !Phase::CdSweep.is_parallel());
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = obs_test_guard();
        set_enabled(false);
        reset();
        {
            let _t = SpanTimer::start(Phase::CdSweep);
        }
        let snap = snapshot_phases();
        assert_eq!(snap[Phase::CdSweep.index()].count, 0);
    }

    #[test]
    fn nested_spans_split_total_into_self_times() {
        let _g = obs_test_guard();
        set_enabled(true);
        reset();
        {
            let _outer = SpanTimer::start(Phase::Fit);
            std::thread::sleep(std::time::Duration::from_millis(4));
            {
                let _inner = SpanTimer::start(Phase::CdSweep);
                std::thread::sleep(std::time::Duration::from_millis(4));
            }
        }
        let snap = snapshot_phases();
        set_enabled(false);
        let outer = &snap[Phase::Fit.index()];
        let inner = &snap[Phase::CdSweep.index()];
        assert_eq!(outer.count, 1);
        assert_eq!(inner.count, 1);
        // The inner span is undivided; the outer's self-time excludes it.
        assert_eq!(inner.self_ns, inner.total_ns);
        assert!(outer.total_ns >= inner.total_ns);
        assert!(
            outer.self_ns <= outer.total_ns - inner.total_ns,
            "outer self {} must exclude inner total {} (outer total {})",
            outer.self_ns,
            inner.total_ns,
            outer.total_ns
        );
        // Self-times of all phases sum to the root's total (single
        // thread, everything nested under Fit) — the invariant the
        // profile's wall reconciliation rests on.
        let self_sum: u64 = snap.iter().map(|s| s.self_ns).sum();
        assert_eq!(self_sum, outer.total_ns);
        assert_eq!(inner.buckets.iter().sum::<u64>(), 1);
        reset();
        assert_eq!(snapshot_phases()[Phase::Fit.index()].count, 0);
    }

    #[test]
    fn sibling_spans_restore_the_parent_child_accumulator() {
        let _g = obs_test_guard();
        set_enabled(true);
        reset();
        {
            let _outer = SpanTimer::start(Phase::Fit);
            for _ in 0..3 {
                let _inner = SpanTimer::start(Phase::DerivativePass);
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
        let snap = snapshot_phases();
        set_enabled(false);
        let outer = &snap[Phase::Fit.index()];
        let inner = &snap[Phase::DerivativePass.index()];
        assert_eq!(inner.count, 3);
        // All three siblings subtract from the parent exactly once.
        assert!(outer.self_ns <= outer.total_ns.saturating_sub(inner.total_ns));
        reset();
    }
}
