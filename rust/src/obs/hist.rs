//! The crate's one log₂-bucketed duration histogram, shared by the
//! serving metrics (`serve/stats.rs`) and the training-side span
//! tracer (`obs/span.rs`).
//!
//! Bucket semantics (the single source of truth — the serving and
//! training paths must agree on what a bucket means):
//!
//! - bucket `0` holds exact zeros (a sub-microsecond duration truncates
//!   to 0 µs),
//! - bucket `i` for `1 ≤ i ≤ 38` covers `[2^(i−1), 2^i)` microseconds,
//! - bucket `39` is the open-ended top bucket, absorbing everything
//!   from 2³⁸ µs (~3.2 days) up.
//!
//! Quantile estimates interpolate to the **arithmetic midpoint** of the
//! selected bucket (`1.5·2^(i−1)` µs), so the reported value is within
//! a factor of 1.5 of the true sample in either direction — against the
//! old upper-bound estimate, whose error reached the full bucket width
//! of 2×. Everything is a relaxed atomic: recording is three
//! `fetch_add`s, and readers observe a consistent-enough snapshot
//! without blocking writers.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of log₂ buckets (see the module docs for the edge semantics).
pub const N_BUCKETS: usize = 40;

/// Bucket index for a duration in microseconds.
pub fn bucket_of(us: u64) -> usize {
    if us == 0 {
        0
    } else {
        (64 - us.leading_zeros() as usize).min(N_BUCKETS - 1)
    }
}

/// Inclusive lower bound of bucket `i` in microseconds.
pub fn bucket_lower_us(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << (i - 1)
    }
}

/// Exclusive upper bound of bucket `i` in microseconds (the top bucket
/// reports its lower bound — it has no finite width).
pub fn bucket_upper_us(i: usize) -> u64 {
    if i >= N_BUCKETS - 1 {
        1u64 << (N_BUCKETS - 2)
    } else {
        1u64 << i
    }
}

/// The midpoint a quantile estimate reports for bucket `i`: 0 for the
/// zero bucket, the lower bound for the unbounded top bucket, and the
/// arithmetic midpoint `1.5·2^(i−1)` everywhere else.
pub fn bucket_midpoint_us(i: usize) -> f64 {
    if i == 0 {
        0.0
    } else if i >= N_BUCKETS - 1 {
        (1u64 << (N_BUCKETS - 2)) as f64
    } else {
        1.5 * (1u64 << (i - 1)) as f64
    }
}

/// Midpoint-interpolated quantile over a raw bucket-count array — the
/// `profile` subcommand estimates quantiles from counts deserialized
/// out of a trace file, where no live histogram exists. `q` in [0, 1];
/// returns 0 for an empty array.
pub fn quantile_from_counts(counts: &[u64], q: f64) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let target = ((q * total as f64).ceil() as u64).clamp(1, total);
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        cum += c;
        if cum >= target {
            return bucket_midpoint_us(i);
        }
    }
    bucket_midpoint_us(counts.len().saturating_sub(1))
}

/// Append one conformant Prometheus cumulative histogram to `out`:
/// every finite bucket boundary (`le` = the bucket's inclusive integer
/// upper bound, `2^i − 1`, with `0` for the zero bucket), the mandatory
/// `+Inf` bucket, and the `_sum`/`_count` series. The boundary set is
/// fixed per metric — empty buckets are emitted too, so `le` label sets
/// never vary between scrapes (rate() over `_bucket` series needs
/// stable boundaries). `labels` is the label set without `le` (may be
/// empty); `count`/`sum` must come from the same snapshot as `buckets`.
pub fn write_prom_cumulative(
    out: &mut String,
    metric: &str,
    labels: &str,
    buckets: &[u64; N_BUCKETS],
    count: u64,
    sum: u64,
) {
    use std::fmt::Write as _;
    let sep = if labels.is_empty() { "" } else { "," };
    let mut cum = 0u64;
    for (i, &c) in buckets.iter().enumerate().take(N_BUCKETS - 1) {
        cum += c;
        let le = if i == 0 { 0 } else { bucket_upper_us(i) - 1 };
        let _ = writeln!(out, "{metric}_bucket{{{labels}{sep}le=\"{le}\"}} {cum}");
    }
    let _ = writeln!(out, "{metric}_bucket{{{labels}{sep}le=\"+Inf\"}} {count}");
    if labels.is_empty() {
        let _ = writeln!(out, "{metric}_sum {sum}");
        let _ = writeln!(out, "{metric}_count {count}");
    } else {
        let _ = writeln!(out, "{metric}_sum{{{labels}}} {sum}");
        let _ = writeln!(out, "{metric}_count{{{labels}}} {count}");
    }
}

/// Log₂-bucketed duration histogram over microseconds.
pub struct LatencyHistogram {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl LatencyHistogram {
    /// Const constructor so histograms can live in `static` phase
    /// tables (the span tracer's per-phase stats are a static array).
    pub const fn new() -> Self {
        const ZERO: AtomicU64 = AtomicU64::new(0);
        LatencyHistogram {
            buckets: [ZERO; N_BUCKETS],
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    pub fn record(&self, us: u64) {
        self.buckets[bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Total recorded microseconds (the Prometheus `_sum` series).
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Mean duration in microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us() as f64 / n as f64
        }
    }

    /// Snapshot of the raw per-bucket counts.
    pub fn bucket_counts(&self) -> [u64; N_BUCKETS] {
        let mut out = [0u64; N_BUCKETS];
        for (o, b) in out.iter_mut().zip(self.buckets.iter()) {
            *o = b.load(Ordering::Relaxed);
        }
        out
    }

    /// Midpoint-interpolated quantile estimate in microseconds (0 when
    /// empty). `q` in [0, 1].
    pub fn quantile_us(&self, q: f64) -> f64 {
        quantile_from_counts(&self.bucket_counts(), q)
    }

    /// Zero every counter (the span tracer resets between traced runs).
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_us.store(0, Ordering::Relaxed);
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_are_exact() {
        // The zero bucket holds exactly {0}; 1 µs starts bucket 1.
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        // 2^k − 1 is the last value of bucket k; 2^k opens bucket k+1.
        for k in [1usize, 2, 5, 10, 20, 37] {
            assert_eq!(bucket_of((1u64 << k) - 1), k, "2^{k}-1");
            assert_eq!(bucket_of(1u64 << k), k + 1, "2^{k}");
        }
        // Top-bucket overflow: 2^38 and everything above land in 39.
        assert_eq!(bucket_of((1u64 << 38) - 1), N_BUCKETS - 2);
        assert_eq!(bucket_of(1u64 << 38), N_BUCKETS - 1);
        assert_eq!(bucket_of(u64::MAX), N_BUCKETS - 1);
        // Bounds agree with bucket_of on both edges.
        for i in 1..N_BUCKETS - 1 {
            assert_eq!(bucket_of(bucket_lower_us(i)), i);
            assert_eq!(bucket_of(bucket_upper_us(i) - 1), i);
        }
    }

    #[test]
    fn quantiles_interpolate_to_bucket_midpoints() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile_us(0.5), 0.0, "empty histogram");
        // A lone sample of 100 µs sits in bucket 7 = [64, 128): the
        // midpoint estimate is 96, within 1.5× of the true value —
        // the old upper-bound estimate reported 128 (1.28×, and up to
        // 2× in the worst case).
        h.record(100);
        assert_eq!(h.quantile_us(0.5), 96.0);
        let ratio = h.quantile_us(0.5) / 100.0;
        assert!((0.666..=1.5).contains(&ratio));
        // Zeros report zero, the top bucket reports its lower bound.
        let h = LatencyHistogram::default();
        h.record(0);
        assert_eq!(h.quantile_us(0.99), 0.0);
        let h = LatencyHistogram::default();
        h.record(u64::MAX);
        assert_eq!(h.quantile_us(0.5), (1u64 << 38) as f64);
    }

    #[test]
    fn quantiles_are_monotone_and_cover_the_spread() {
        let h = LatencyHistogram::default();
        for us in [10u64, 20, 40, 80, 160, 1000, 5000] {
            h.record(us);
        }
        let p50 = h.quantile_us(0.50);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p99);
        // Median sample 80 → bucket [64,128) midpoint 96; max sample
        // 5000 → bucket [4096,8192) midpoint 6144.
        assert_eq!(p50, 96.0);
        assert_eq!(p99, 6144.0);
        assert_eq!(h.count(), 7);
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.sum_us(), 6310);
    }

    #[test]
    fn prometheus_cumulative_exposition_has_fixed_boundaries() {
        let h = LatencyHistogram::default();
        h.record(300); // bucket [256, 512) → le="511"
        h.record(1200); // bucket [1024, 2048) → le="2047"
        let mut out = String::new();
        write_prom_cumulative(
            &mut out,
            "t_us",
            "endpoint=\"score\"",
            &h.bucket_counts(),
            h.count(),
            h.sum_us(),
        );
        for line in [
            "t_us_bucket{endpoint=\"score\",le=\"0\"} 0",
            "t_us_bucket{endpoint=\"score\",le=\"255\"} 0",
            "t_us_bucket{endpoint=\"score\",le=\"511\"} 1",
            "t_us_bucket{endpoint=\"score\",le=\"1023\"} 1",
            "t_us_bucket{endpoint=\"score\",le=\"2047\"} 2",
            "t_us_bucket{endpoint=\"score\",le=\"+Inf\"} 2",
            "t_us_sum{endpoint=\"score\"} 1500",
            "t_us_count{endpoint=\"score\"} 2",
        ] {
            assert!(out.contains(line), "missing {line:?} in:\n{out}");
        }
        // Every finite boundary appears exactly once (fixed le set),
        // plus +Inf: N_BUCKETS lines of _bucket in total.
        assert_eq!(out.matches("t_us_bucket{").count(), N_BUCKETS);
        // Unlabeled metrics still get a syntactically valid le set.
        let mut bare = String::new();
        write_prom_cumulative(&mut bare, "b_us", "", &h.bucket_counts(), 2, 1500);
        assert!(bare.contains("b_us_bucket{le=\"0\"} 0"), "{bare}");
        assert!(bare.contains("b_us_sum 1500"), "{bare}");
    }

    #[test]
    fn reset_and_counts_round_trip() {
        let h = LatencyHistogram::default();
        h.record(3);
        h.record(1024);
        let counts = h.bucket_counts();
        assert_eq!(counts[bucket_of(3)], 1);
        assert_eq!(counts[bucket_of(1024)], 1);
        assert_eq!(quantile_from_counts(&counts, 0.0), bucket_midpoint_us(2));
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_us(0.5), 0.0);
    }
}
