//! Emission: whole-process snapshots, per-fit [`FitReport`]s attached to
//! model diagnostics, and the `--trace-out` JSONL trace stream.
//!
//! A [`FitReport`] is a *diff*: the API builder snapshots the global
//! sink before a fit and captures everything accumulated since, so
//! reports stay per-fit even when several fits run in one process (the
//! watch loop). The JSONL trace is aggregate-per-phase, not
//! per-span-event — one `meta` line, one `phase` line per non-empty
//! phase (with its log₂ bucket counts), and one `counters` line — which
//! keeps writes off the hot path entirely: the file is written once,
//! after the run.

use super::counters::{counter_snapshot, CounterSnapshot};
use super::span::{snapshot_phases, Phase, PhaseSnapshot};
use crate::api::json::{self, Json};
use crate::error::{FastSurvivalError, Result};

/// Schema version stamped on the `meta` line of every trace file.
pub const TRACE_SCHEMA_VERSION: u64 = 1;

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

/// A point-in-time copy of the whole sink (every phase + every
/// counter), used as the "before" edge of a [`FitReport`] diff.
#[derive(Clone, Debug)]
pub struct ObsSnapshot {
    pub phases: Vec<PhaseSnapshot>,
    pub counters: CounterSnapshot,
}

/// Snapshot the global sink.
pub fn obs_snapshot() -> ObsSnapshot {
    ObsSnapshot { phases: snapshot_phases(), counters: counter_snapshot() }
}

/// One phase's share of a [`FitReport`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PhaseReport {
    /// Stable snake_case phase name ([`Phase::name`]).
    pub phase: String,
    pub count: u64,
    pub total_ns: u64,
    pub self_ns: u64,
}

/// Per-fit telemetry summary, serialized into `CoxModel`/`CoxPath`
/// diagnostics. Empty (no phases, zero counters) when tracing was off.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FitReport {
    /// Non-empty phases only, in stats-table order.
    pub phases: Vec<PhaseReport>,
    pub counters: CounterSnapshot,
}

impl FitReport {
    /// Diff the sink against a snapshot taken before the fit, keeping
    /// only phases that recorded at least one span since.
    pub fn capture_since(before: &ObsSnapshot) -> FitReport {
        let now = obs_snapshot();
        let phases = now
            .phases
            .iter()
            .zip(before.phases.iter())
            .filter(|(n, b)| n.count > b.count)
            .map(|(n, b)| PhaseReport {
                phase: n.phase.name().to_string(),
                count: n.count - b.count,
                total_ns: n.total_ns.saturating_sub(b.total_ns),
                self_ns: n.self_ns.saturating_sub(b.self_ns),
            })
            .collect();
        FitReport { phases, counters: now.counters.since(&before.counters) }
    }

    /// True when nothing was recorded (tracing off for the whole fit).
    pub fn is_empty(&self) -> bool {
        self.phases.is_empty() && self.counters == CounterSnapshot::default()
    }

    fn to_json_value(&self) -> Json {
        let phases = self
            .phases
            .iter()
            .map(|p| {
                Json::Obj(vec![
                    ("phase".to_string(), Json::Str(p.phase.clone())),
                    ("count".to_string(), num(p.count)),
                    ("total_ns".to_string(), num(p.total_ns)),
                    ("self_ns".to_string(), num(p.self_ns)),
                ])
            })
            .collect();
        let counters = self
            .counters
            .fields()
            .iter()
            .map(|&(k, v)| (k.to_string(), num(v)))
            .collect();
        Json::Obj(vec![
            ("phases".to_string(), Json::Arr(phases)),
            ("counters".to_string(), Json::Obj(counters)),
        ])
    }

    /// Append this report as a compact JSON object. Counts are stored
    /// as JSON numbers (f64-exact up to 2⁵³ — ~104 days of nanoseconds,
    /// far past any fit this records).
    pub fn write_json(&self, out: &mut String) {
        self.to_json_value().write_to(out);
    }

    /// Parse a report written by [`FitReport::write_json`].
    pub fn from_json(doc: &Json) -> Result<FitReport> {
        let mut phases = Vec::new();
        for p in doc.require("phases")?.as_array()? {
            phases.push(PhaseReport {
                phase: p.require("phase")?.as_str()?.to_string(),
                count: p.require("count")?.as_f64()? as u64,
                total_ns: p.require("total_ns")?.as_f64()? as u64,
                self_ns: p.require("self_ns")?.as_f64()? as u64,
            });
        }
        let counters = match doc.require("counters")? {
            Json::Obj(fields) => CounterSnapshot::from_fields(
                fields
                    .iter()
                    .filter_map(|(k, v)| v.as_f64().ok().map(|x| (k.as_str(), x as u64))),
            ),
            other => {
                return Err(FastSurvivalError::Persist(format!(
                    "expected counters object, found {other:?}"
                )))
            }
        };
        Ok(FitReport { phases, counters })
    }
}

/// Render the current sink as a JSONL trace document (see the module
/// docs for the line schema). `cmd` names the CLI command that ran;
/// `wall_secs`/`threads` go on the `meta` line so `profile` can
/// reconcile phase self-times against the wall clock.
pub fn render_trace_jsonl(cmd: &str, wall_secs: f64, threads: usize) -> String {
    let mut out = String::new();
    Json::Obj(vec![
        ("event".to_string(), Json::Str("meta".to_string())),
        ("schema_version".to_string(), num(TRACE_SCHEMA_VERSION)),
        ("cmd".to_string(), Json::Str(cmd.to_string())),
        ("wall_secs".to_string(), Json::Num(wall_secs)),
        ("threads".to_string(), num(threads as u64)),
    ])
    .write_to(&mut out);
    out.push('\n');
    for snap in snapshot_phases() {
        if snap.count == 0 {
            continue;
        }
        let buckets = snap.buckets.iter().map(|&b| num(b)).collect();
        Json::Obj(vec![
            ("event".to_string(), Json::Str("phase".to_string())),
            ("phase".to_string(), Json::Str(snap.phase.name().to_string())),
            ("parallel".to_string(), Json::Bool(snap.phase.is_parallel())),
            ("count".to_string(), num(snap.count)),
            ("total_ns".to_string(), num(snap.total_ns)),
            ("self_ns".to_string(), num(snap.self_ns)),
            ("buckets_us_log2".to_string(), Json::Arr(buckets)),
        ])
        .write_to(&mut out);
        out.push('\n');
    }
    let mut counter_fields = vec![("event".to_string(), Json::Str("counters".to_string()))];
    for (k, v) in counter_snapshot().fields() {
        counter_fields.push((k.to_string(), num(v)));
    }
    Json::Obj(counter_fields).write_to(&mut out);
    out.push('\n');
    out
}

/// Write the current sink to `path` as a JSONL trace file.
pub fn write_trace_jsonl(path: &str, cmd: &str, wall_secs: f64, threads: usize) -> Result<()> {
    std::fs::write(path, render_trace_jsonl(cmd, wall_secs, threads))
        .map_err(|e| FastSurvivalError::Persist(format!("writing trace {path}: {e}")))
}

/// One `phase` line parsed back out of a trace file.
#[derive(Clone, Debug)]
pub struct TracePhaseLine {
    pub phase: String,
    pub parallel: bool,
    pub count: u64,
    pub total_ns: u64,
    pub self_ns: u64,
    pub buckets_us_log2: Vec<u64>,
}

/// A parsed trace document (the `profile` subcommand's input).
#[derive(Clone, Debug, Default)]
pub struct TraceDoc {
    pub cmd: String,
    pub wall_secs: f64,
    pub threads: u64,
    pub phases: Vec<TracePhaseLine>,
    pub counters: CounterSnapshot,
}

/// Parse JSONL trace text (as written by [`write_trace_jsonl`]). Blank
/// lines are skipped; unknown event kinds are ignored so the schema can
/// grow without breaking old readers.
pub fn parse_trace_jsonl(text: &str) -> Result<TraceDoc> {
    let mut doc = TraceDoc::default();
    let mut saw_meta = false;
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(line).map_err(|e| {
            FastSurvivalError::Persist(format!("trace line {}: {e}", lineno + 1))
        })?;
        match v.require("event")?.as_str()? {
            "meta" => {
                saw_meta = true;
                doc.cmd = v.require("cmd")?.as_str()?.to_string();
                doc.wall_secs = v.require("wall_secs")?.as_f64()?;
                doc.threads = v.require("threads")?.as_f64()? as u64;
            }
            "phase" => {
                let name = v.require("phase")?.as_str()?.to_string();
                // The parallel flag is authoritative from the file, but
                // fall back to the compiled-in taxonomy when absent.
                let parallel = match v.get("parallel") {
                    Some(b) => b.as_bool()?,
                    None => Phase::from_name(&name).is_some_and(Phase::is_parallel),
                };
                let buckets = v
                    .require("buckets_us_log2")?
                    .as_f64_vec()?
                    .into_iter()
                    .map(|x| x as u64)
                    .collect();
                doc.phases.push(TracePhaseLine {
                    phase: name,
                    parallel,
                    count: v.require("count")?.as_f64()? as u64,
                    total_ns: v.require("total_ns")?.as_f64()? as u64,
                    self_ns: v.require("self_ns")?.as_f64()? as u64,
                    buckets_us_log2: buckets,
                });
            }
            "counters" => {
                if let Json::Obj(fields) = &v {
                    doc.counters = CounterSnapshot::from_fields(
                        fields
                            .iter()
                            .filter(|(k, _)| k != "event")
                            .filter_map(|(k, v)| {
                                v.as_f64().ok().map(|x| (k.as_str(), x as u64))
                            }),
                    );
                }
            }
            _ => {}
        }
    }
    if !saw_meta {
        return Err(FastSurvivalError::Persist(
            "trace file has no meta line (is this a --trace-out file?)".to_string(),
        ));
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::super::span::test_support::obs_test_guard;
    use super::super::span::{reset, set_enabled, SpanTimer};
    use super::super::counters;
    use super::*;

    #[test]
    fn fit_report_diffs_the_sink_and_round_trips_through_json() {
        let _g = obs_test_guard();
        set_enabled(true);
        reset();
        // Pre-existing noise the diff must exclude.
        {
            let _t = SpanTimer::start(Phase::CdSweep);
        }
        counters::kernel_calls(false, 5);
        let before = obs_snapshot();
        {
            let _fit = SpanTimer::start(Phase::Fit);
            let _t = SpanTimer::start(Phase::DerivativePass);
            counters::kernel_calls(true, 8);
            counters::workspace_cache(true);
        }
        let report = FitReport::capture_since(&before);
        set_enabled(false);
        assert!(!report.is_empty());
        let names: Vec<&str> = report.phases.iter().map(|p| p.phase.as_str()).collect();
        assert_eq!(names, vec!["fit", "derivative_pass"], "diff keeps only new phases");
        assert!(report.phases.iter().all(|p| p.count == 1));
        assert_eq!(report.counters.kernel_simd, 8);
        assert_eq!(report.counters.kernel_scalar, 0, "pre-snapshot counts excluded");
        assert_eq!(report.counters.workspace_hits, 1);

        let mut text = String::new();
        report.write_json(&mut text);
        let parsed = FitReport::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, report);
        reset();
    }

    #[test]
    fn empty_report_when_tracing_is_off() {
        let _g = obs_test_guard();
        set_enabled(false);
        reset();
        let before = obs_snapshot();
        {
            let _t = SpanTimer::start(Phase::Fit);
            counters::kernel_calls(true, 8);
        }
        let report = FitReport::capture_since(&before);
        assert!(report.is_empty());
    }

    #[test]
    fn trace_jsonl_round_trips() {
        let _g = obs_test_guard();
        set_enabled(true);
        reset();
        {
            let _fit = SpanTimer::start(Phase::Fit);
            {
                let _t = SpanTimer::start(Phase::StreamExactSweep);
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            let _t = SpanTimer::start(Phase::ShardScan);
        }
        counters::shard_cmd(counters::ShardCmdKind::Scan);
        let text = render_trace_jsonl("bigfit", 1.25, 4);
        set_enabled(false);
        reset();

        let doc = parse_trace_jsonl(&text).unwrap();
        assert_eq!(doc.cmd, "bigfit");
        assert_eq!(doc.wall_secs, 1.25);
        assert_eq!(doc.threads, 4);
        assert_eq!(doc.counters.shard_scan_cmds, 1);
        let sweep = doc.phases.iter().find(|p| p.phase == "stream_exact_sweep").unwrap();
        assert!(!sweep.parallel);
        assert_eq!(sweep.count, 1);
        assert!(sweep.total_ns >= 1_000_000);
        assert_eq!(sweep.buckets_us_log2.iter().sum::<u64>(), 1);
        let scan = doc.phases.iter().find(|p| p.phase == "shard_scan").unwrap();
        assert!(scan.parallel);
        // Zero-count phases are omitted from the file.
        assert!(doc.phases.iter().all(|p| p.count > 0));
        assert!(!doc.phases.iter().any(|p| p.phase == "cd_sweep"));
    }

    #[test]
    fn trace_parser_rejects_garbage_and_missing_meta() {
        assert!(parse_trace_jsonl("not json\n").is_err());
        assert!(parse_trace_jsonl("{\"event\":\"phase\"}\n").is_err());
        assert!(parse_trace_jsonl("").is_err());
    }
}
