//! Analytic minimizers of the surrogate subproblems (Eqs. 17, 18, 20, 22).
//!
//! The ℓ1-regularized cubic subproblem is solved by exact enumeration of
//! the stationary points of each smooth piece (the function is convex and
//! piecewise smooth with kinks at Δ = 0 and Δ = −d). This is equivalent
//! to the paper's closed-form case table (Eq. 22) but immune to the sign
//! subtleties of the unified formula; a test checks the two agree on the
//! paper's first case.

/// Minimizer of the quadratic surrogate g(Δ) = f + aΔ + ½bΔ² (Eq. 17).
#[inline]
pub fn quad_step(a: f64, b: f64) -> f64 {
    if b <= 0.0 {
        return 0.0; // flat coordinate: no information, no move
    }
    -a / b
}

/// Minimizer of aΔ + ½bΔ² + λ1|c+Δ| (Eq. 20): the ℓ1 quadratic surrogate.
/// `a` = (penalized) first derivative, `b` = Lipschitz constant, `c` = β_l.
pub fn quad_l1_step(a: f64, b: f64, c: f64, lambda1: f64) -> f64 {
    debug_assert!(b > 0.0);
    let bc_a = b * c - a;
    if bc_a < -lambda1 {
        -(a - lambda1) / b
    } else if bc_a > lambda1 {
        -(a + lambda1) / b
    } else {
        -c
    }
}

/// Minimizer of the cubic surrogate h(Δ) = f + aΔ + ½bΔ² + (c/6)|Δ|³
/// (Eq. 18), in the cancellation-free form
/// Δ = −2a / (b + √(b² + 2c|a|)).
pub fn cubic_step(a: f64, b: f64, c: f64) -> f64 {
    debug_assert!(b >= -1e-12, "second derivative must be >= 0 (convexity)");
    let b = b.max(0.0);
    let denom = b + (b * b + 2.0 * c * a.abs()).sqrt();
    if denom <= 0.0 {
        return 0.0; // a == 0 or totally flat
    }
    -2.0 * a / denom
}

/// Value of the ℓ1 cubic surrogate objective (without the constant f(x)).
#[inline]
fn cubic_l1_value(delta: f64, a: f64, b: f64, c: f64, d: f64, lambda1: f64) -> f64 {
    a * delta + 0.5 * b * delta * delta + c / 6.0 * delta.abs().powi(3) + lambda1 * (d + delta).abs()
}

/// Minimizer of aΔ + ½bΔ² + (c/6)|Δ|³ + λ1|d+Δ| (Eq. 21/22): the
/// ℓ1-regularized cubic surrogate. Exact via per-piece stationary points.
pub fn cubic_l1_step(a: f64, b: f64, c: f64, d: f64, lambda1: f64) -> f64 {
    debug_assert!(b >= -1e-12 && c >= 0.0);
    let b = b.max(0.0);
    if lambda1 == 0.0 {
        return cubic_step(a, b, c);
    }
    // Breakpoints of |Δ| and |d+Δ|.
    let mut candidates = vec![0.0, -d];

    // Smooth pieces: sign(Δ) = sc, sign(d+Δ) = sl. On a piece,
    // φ'(Δ) = a + bΔ + sc·(c/2)·Δ² + sl·λ1 = 0.
    let push_roots = |sc: f64, sl: f64, lo: f64, hi: f64, out: &mut Vec<f64>| {
        let a_eff = a + sl * lambda1;
        let half_c = sc * 0.5 * c;
        if half_c.abs() < 1e-300 {
            // Linear: bΔ + a_eff = 0.
            if b > 0.0 {
                let r = -a_eff / b;
                if r > lo && r < hi {
                    out.push(r);
                }
            }
        } else {
            let disc = b * b - 4.0 * half_c * a_eff;
            if disc >= 0.0 {
                let sq = disc.sqrt();
                for r in [(-b + sq) / (2.0 * half_c), (-b - sq) / (2.0 * half_c)] {
                    if r > lo && r < hi {
                        out.push(r);
                    }
                }
            }
        }
    };

    // Region boundaries sorted.
    let (b1, b2) = if -d < 0.0 { (-d, 0.0) } else { (0.0, -d) };
    let mut roots = Vec::new();
    // Three open regions; evaluate each with the correct signs.
    for (lo, hi) in [(f64::NEG_INFINITY, b1), (b1, b2), (b2, f64::INFINITY)] {
        if lo >= hi {
            continue;
        }
        // Pick a probe point to determine signs in this region.
        let probe = if lo.is_infinite() {
            hi - 1.0
        } else if hi.is_infinite() {
            lo + 1.0
        } else {
            0.5 * (lo + hi)
        };
        let sc = if probe >= 0.0 { 1.0 } else { -1.0 };
        let sl = if d + probe >= 0.0 { 1.0 } else { -1.0 };
        push_roots(sc, sl, lo, hi, &mut roots);
    }
    candidates.extend(roots);

    let mut best = candidates[0];
    let mut best_v = cubic_l1_value(best, a, b, c, d, lambda1);
    for &cand in &candidates[1..] {
        let v = cubic_l1_value(cand, a, b, c, d, lambda1);
        if v < best_v {
            best_v = v;
            best = cand;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn quad_l1_value(delta: f64, a: f64, b: f64, c: f64, l1: f64) -> f64 {
        a * delta + 0.5 * b * delta * delta + l1 * (c + delta).abs()
    }

    /// Golden-section minimization for convex 1-D reference.
    fn golden_min(f: impl Fn(f64) -> f64, mut lo: f64, mut hi: f64) -> f64 {
        let phi = (5.0_f64.sqrt() - 1.0) / 2.0;
        for _ in 0..200 {
            let m1 = hi - phi * (hi - lo);
            let m2 = lo + phi * (hi - lo);
            if f(m1) < f(m2) {
                hi = m2;
            } else {
                lo = m1;
            }
        }
        0.5 * (lo + hi)
    }

    #[test]
    fn quad_step_is_newton_on_surrogate() {
        assert_eq!(quad_step(2.0, 4.0), -0.5);
        assert_eq!(quad_step(1.0, 0.0), 0.0);
    }

    #[test]
    fn quad_l1_matches_golden_section() {
        check(
            "quad-l1-optimal",
            11,
            60,
            |r| {
                (
                    r.uniform_range(-5.0, 5.0),
                    r.uniform_range(0.1, 10.0),
                    r.uniform_range(-3.0, 3.0),
                    r.uniform_range(0.0, 4.0),
                )
            },
            |&(a, b, c, l1)| {
                let ours = quad_l1_step(a, b, c, l1);
                let gold = golden_min(|d| quad_l1_value(d, a, b, c, l1), -50.0, 50.0);
                let vo = quad_l1_value(ours, a, b, c, l1);
                let vg = quad_l1_value(gold, a, b, c, l1);
                if vo <= vg + 1e-8 {
                    Ok(())
                } else {
                    Err(format!("ours={ours} (v={vo}) vs golden={gold} (v={vg})"))
                }
            },
        );
    }

    #[test]
    fn quad_l1_zero_sticks_at_zero() {
        // If |a| <= λ1 and c = 0 the solution stays exactly 0.
        assert_eq!(quad_l1_step(0.5, 2.0, 0.0, 1.0), 0.0);
        assert_eq!(quad_l1_step(-0.9, 2.0, 0.0, 1.0), 0.0);
        assert!(quad_l1_step(1.5, 2.0, 0.0, 1.0) != 0.0);
    }

    #[test]
    fn cubic_step_matches_paper_closed_form() {
        // Stable form must equal Eq. (18) where that is well-conditioned.
        for (a, b, c) in [(1.0, 2.0, 3.0), (-2.0, 0.5, 1.0), (0.7, 0.0, 2.0)] {
            let stable = cubic_step(a, b, c);
            let paper = a.signum() * (b - (b * b + 2.0 * c * a.abs()).sqrt()) / c;
            assert!((stable - paper).abs() < 1e-10, "{stable} vs {paper}");
        }
    }

    #[test]
    fn cubic_step_reduces_to_newtonish_when_c_zero() {
        assert!((cubic_step(2.0, 4.0, 0.0) + 0.5).abs() < 1e-15);
    }

    #[test]
    fn cubic_step_minimizes_surrogate() {
        check(
            "cubic-step-optimal",
            13,
            60,
            |r| {
                (
                    r.uniform_range(-5.0, 5.0),
                    r.uniform_range(0.0, 5.0),
                    r.uniform_range(0.01, 5.0),
                )
            },
            |&(a, b, c)| {
                let h = |d: f64| a * d + 0.5 * b * d * d + c / 6.0 * d.abs().powi(3);
                let ours = cubic_step(a, b, c);
                let gold = golden_min(h, -100.0, 100.0);
                if h(ours) <= h(gold) + 1e-8 {
                    Ok(())
                } else {
                    Err(format!("ours={ours} h={} gold h={}", h(ours), h(gold)))
                }
            },
        );
    }

    #[test]
    fn cubic_l1_matches_golden_section() {
        check(
            "cubic-l1-optimal",
            17,
            100,
            |r| {
                (
                    r.uniform_range(-5.0, 5.0),
                    r.uniform_range(0.0, 5.0),
                    r.uniform_range(0.0, 5.0),
                    r.uniform_range(-3.0, 3.0),
                    r.uniform_range(0.0, 4.0),
                )
            },
            |&(a, b, c, d, l1)| {
                // Keep the objective strictly convex enough for golden search.
                if b < 1e-6 && c < 1e-6 {
                    return Ok(());
                }
                let ours = cubic_l1_step(a, b, c, d, l1);
                let gold = golden_min(|x| cubic_l1_value(x, a, b, c, d, l1), -60.0, 60.0);
                let vo = cubic_l1_value(ours, a, b, c, d, l1);
                let vg = cubic_l1_value(gold, a, b, c, d, l1);
                if vo <= vg + 1e-7 {
                    Ok(())
                } else {
                    Err(format!("ours={ours} v={vo} vs golden={gold} v={vg}"))
                }
            },
        );
    }

    #[test]
    fn cubic_l1_agrees_with_paper_case_one() {
        // Paper Eq. (22) first case: sgn(d)a + λ1 <= 0.
        let (b, c, l1) = (1.0, 2.0, 0.5);
        let d = 1.0_f64;
        let a = -2.0; // sgn(d) a + λ1 = -1.5 <= 0
        let paper = d.signum() * (-b + (b * b - 2.0 * c * (d.signum() * a + l1)).sqrt()) / c;
        let ours = cubic_l1_step(a, b, c, d, l1);
        assert!((ours - paper).abs() < 1e-10, "{ours} vs {paper}");
    }

    #[test]
    fn cubic_l1_snaps_to_minus_d() {
        // Large λ1 forces β + Δ = 0, i.e. Δ = −d.
        let ours = cubic_l1_step(0.1, 1.0, 1.0, 0.7, 100.0);
        assert!((ours + 0.7).abs() < 1e-12);
    }
}
