//! (Proximal) gradient descent baseline — Appendix B's "one way to train
//! the CPH model" whose step-size problem motivates the paper.
//!
//! Step size 1/L with L = Σ_l L2_l + 2λ2 (trace bound on the β-space
//! Hessian, valid globally via Theorem 3.4). With λ1 > 0 the update is
//! the proximal (ISTA) step.

use super::objective::{require_native, FitConfig, FitResult, Optimizer, Stopper};
use crate::cox::derivatives::beta_gradient;
use crate::cox::lipschitz::all_lipschitz;
use crate::cox::{CoxProblem, CoxState};
use crate::error::Result;
use crate::linalg::vecops::soft_threshold;
use crate::runtime::engine::CoxEngine;

#[derive(Clone, Copy, Debug, Default)]
pub struct GradientDescent {
    /// Optional fixed step size override (0 = use 1/L).
    pub step_size: f64,
}

impl Optimizer for GradientDescent {
    fn name(&self) -> &'static str {
        "gradient-descent"
    }

    fn fit_from(
        &self,
        problem: &CoxProblem,
        mut state: CoxState,
        config: &FitConfig,
        engine: &dyn CoxEngine,
    ) -> Result<FitResult> {
        require_native(self.name(), engine)?;
        let obj = config.objective;
        let lr = if self.step_size > 0.0 {
            self.step_size
        } else {
            let lip_sum: f64 = all_lipschitz(problem).iter().map(|l| l.l2).sum();
            1.0 / (lip_sum + 2.0 * obj.l2).max(1e-12)
        };
        let mut stopper = Stopper::new();
        let mut iters = 0;
        for it in 0..config.max_iters {
            let g = beta_gradient(problem, &state);
            let new_beta: Vec<f64> = (0..problem.p())
                .map(|l| {
                    let step = state.beta[l] - lr * (g[l] + 2.0 * obj.l2 * state.beta[l]);
                    if obj.l1 > 0.0 {
                        soft_threshold(step, lr * obj.l1)
                    } else {
                        step
                    }
                })
                .collect();
            state.set_beta(problem, &new_beta);
            iters = it + 1;
            let loss = obj.value(problem, &state);
            if stopper.step(it, loss, config) {
                break;
            }
        }
        let objective_value = obj.value(problem, &state);
        Ok(FitResult { beta: state.beta, trace: stopper.trace, objective_value, iterations: iters })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SurvivalDataset;
    use crate::linalg::Matrix;
    use crate::optim::objective::Objective;
    use crate::optim::QuadraticSurrogate;
    use crate::util::rng::Rng;

    fn random_problem(n: usize, p: usize, seed: u64) -> CoxProblem {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f64>> =
            (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let time: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.5, 9.5)).collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.7)).collect();
        CoxProblem::new(&SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "r"))
    }

    #[test]
    fn monotone_with_one_over_l_step() {
        let pr = random_problem(60, 4, 31);
        let cfg = FitConfig {
            objective: Objective { l1: 0.0, l2: 0.5 },
            max_iters: 100,
            ..Default::default()
        };
        let res = GradientDescent::default().fit(&pr, &cfg).unwrap();
        assert!(res.trace.monotone(1e-9), "1/L descent must be monotone");
    }

    #[test]
    fn slower_than_cd_at_equal_iterations() {
        // The paper's motivation: safe-step GD converges much slower than
        // the surrogate CD (which uses per-coordinate constants).
        let pr = random_problem(80, 5, 32);
        let cfg = FitConfig {
            objective: Objective { l1: 0.0, l2: 1.0 },
            max_iters: 20,
            tol: 0.0,
            ..Default::default()
        };
        let rg = GradientDescent::default().fit(&pr, &cfg).unwrap();
        let rq = QuadraticSurrogate.fit(&pr, &cfg).unwrap();
        assert!(
            rq.objective_value < rg.objective_value - 1e-6,
            "cd {} should beat gd {}",
            rq.objective_value,
            rg.objective_value
        );
    }

    #[test]
    fn ista_yields_sparse_solutions() {
        let pr = random_problem(100, 8, 33);
        let cfg = FitConfig {
            objective: Objective { l1: 10.0, l2: 0.0 },
            max_iters: 500,
            ..Default::default()
        };
        let res = GradientDescent::default().fit(&pr, &cfg).unwrap();
        let nnz = res.beta.iter().filter(|b| b.abs() > 1e-10).count();
        assert!(nnz < pr.p());
    }
}
