//! Quasi-Newton baseline (Section 2, method 2) — Simon, Friedman, Hastie,
//! Tibshirani (2011): the glmnet "coxnet" algorithm.
//!
//! Each outer iteration replaces the η-space Hessian by its diagonal,
//! builds the weighted least-squares working response
//! `z_k = η_k − u_k / w_k`, and solves the penalized WLS problem by
//! coordinate descent. β is replaced wholesale (no step-size control),
//! which is exactly why the loss can increase early on (Figure 1).

use super::objective::{require_native, FitConfig, FitResult, Objective, Optimizer, Stopper};
use crate::cox::derivatives::{eta_gradient, eta_hessian_diag};
use crate::cox::{CoxProblem, CoxState};
use crate::error::Result;
use crate::runtime::engine::CoxEngine;
use crate::linalg::vecops::soft_threshold;

/// Penalized weighted least squares solved by coordinate descent:
/// minimize ½ Σ_k w_k (z_k − x_k^T β)² + λ1‖β‖₁ + λ2‖β‖₂².
/// Returns the new β; `beta` is the warm start.
pub fn wls_coordinate_descent(
    problem: &CoxProblem,
    w: &[f64],
    z: &[f64],
    beta: &[f64],
    obj: Objective,
    max_sweeps: usize,
    tol: f64,
) -> Vec<f64> {
    let p = problem.p();
    let n = problem.n();
    let mut b = beta.to_vec();
    // Residual r = z − Xβ.
    let mut r: Vec<f64> = {
        let eta = problem.x.matvec(&b);
        (0..n).map(|k| z[k] - eta[k]).collect()
    };
    // Nonzero-index lists for binary columns (the Sec-4.2 binarized
    // regime): the ρ scan and the residual update then touch only the
    // supporting samples instead of all n.
    let nz: Vec<Option<Vec<u32>>> = (0..p)
        .map(|l| {
            if problem.col_binary[l] {
                Some(
                    problem
                        .x
                        .col(l)
                        .iter()
                        .enumerate()
                        .filter(|(_, &v)| v != 0.0)
                        .map(|(k, _)| k as u32)
                        .collect(),
                )
            } else {
                None
            }
        })
        .collect();

    // Per-coordinate curvature Σ w x² (constant across sweeps).
    let denom: Vec<f64> = (0..p)
        .map(|l| {
            let base = match &nz[l] {
                Some(idx) => idx.iter().map(|&k| w[k as usize]).sum::<f64>(),
                None => {
                    let col = problem.x.col(l);
                    col.iter().zip(w).map(|(&x, &wk)| wk * x * x).sum::<f64>()
                }
            };
            base + 2.0 * obj.l2
        })
        .collect();

    // One coordinate update; returns |change|.
    let mut update = |l: usize, b: &mut Vec<f64>, r: &mut Vec<f64>| -> f64 {
        if denom[l] <= 0.0 {
            return 0.0;
        }
        // ρ = Σ w x (r + x b_l)
        let mut rho = 0.0;
        match &nz[l] {
            Some(idx) => {
                for &k in idx {
                    let k = k as usize;
                    rho += w[k] * (r[k] + b[l]);
                }
            }
            None => {
                let col = problem.x.col(l);
                for k in 0..n {
                    rho += w[k] * col[k] * (r[k] + col[k] * b[l]);
                }
            }
        }
        let new_b = if obj.l1 > 0.0 {
            soft_threshold(rho, obj.l1) / denom[l]
        } else {
            rho / denom[l]
        };
        let change = new_b - b[l];
        if change != 0.0 {
            match &nz[l] {
                Some(idx) => {
                    for &k in idx {
                        r[k as usize] -= change;
                    }
                }
                None => {
                    let col = problem.x.col(l);
                    for k in 0..n {
                        r[k] -= change * col[k];
                    }
                }
            }
            b[l] = new_b;
        }
        change.abs()
    };

    // glmnet-style active-set cycling: after a full sweep, iterate only
    // on the nonzero coordinates until they stabilize, then verify with
    // another full sweep. Cuts the p-factor dramatically on sparse
    // ℓ1-path fits (the Coxnet workload).
    let mut sweeps_used = 0;
    while sweeps_used < max_sweeps {
        // Full sweep.
        let mut max_change = 0.0_f64;
        for l in 0..p {
            max_change = max_change.max(update(l, &mut b, &mut r));
        }
        sweeps_used += 1;
        if max_change < tol {
            break;
        }
        // Active-set iterations.
        if obj.l1 > 0.0 {
            let active: Vec<usize> =
                (0..p).filter(|&l| b[l] != 0.0).collect();
            while sweeps_used < max_sweeps {
                let mut ch = 0.0_f64;
                for &l in &active {
                    ch = ch.max(update(l, &mut b, &mut r));
                }
                sweeps_used += 1;
                if ch < tol {
                    break;
                }
            }
        }
    }
    b
}

/// Simon et al. quasi-Newton outer loop.
#[derive(Clone, Copy, Debug)]
pub struct QuasiNewton {
    pub inner_sweeps: usize,
    pub inner_tol: f64,
    /// Floor for the diagonal weights (glmnet guards tiny curvature).
    pub weight_floor: f64,
}

impl Default for QuasiNewton {
    fn default() -> Self {
        QuasiNewton { inner_sweeps: 50, inner_tol: 1e-8, weight_floor: 1e-10 }
    }
}

impl Optimizer for QuasiNewton {
    fn name(&self) -> &'static str {
        "quasi-newton"
    }

    fn fit_from(
        &self,
        problem: &CoxProblem,
        mut state: CoxState,
        config: &FitConfig,
        engine: &dyn CoxEngine,
    ) -> Result<FitResult> {
        require_native(self.name(), engine)?;
        let obj = config.objective;
        let mut stopper = Stopper::new();
        let mut iters = 0;
        for it in 0..config.max_iters {
            let u = eta_gradient(problem, &state);
            let mut w = eta_hessian_diag(problem, &state);
            // Working response z = η − u / w, with floored weights.
            let z: Vec<f64> = (0..problem.n())
                .map(|k| {
                    if w[k] < self.weight_floor {
                        w[k] = self.weight_floor;
                    }
                    state.eta[k] - u[k] / w[k]
                })
                .collect();
            let new_beta = wls_coordinate_descent(
                problem,
                &w,
                &z,
                &state.beta,
                obj,
                self.inner_sweeps,
                self.inner_tol,
            );
            state.set_beta(problem, &new_beta);
            iters = it + 1;
            let loss = obj.value(problem, &state);
            if stopper.step(it, loss, config) {
                break;
            }
        }
        let objective_value = obj.value(problem, &state);
        Ok(FitResult { beta: state.beta, trace: stopper.trace, objective_value, iterations: iters })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SurvivalDataset;
    use crate::linalg::Matrix;
    use crate::optim::{CubicSurrogate, QuadraticSurrogate};
    use crate::util::rng::Rng;

    fn random_problem(n: usize, p: usize, seed: u64) -> CoxProblem {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f64>> =
            (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let time: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.5, 9.5)).collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.7)).collect();
        CoxProblem::new(&SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "r"))
    }

    #[test]
    fn wls_solves_ridge_exactly() {
        // With identity design and unit weights, the WLS solution is the
        // soft-thresholded/shrunk target.
        let n = 6;
        let cols: Vec<Vec<f64>> = (0..n)
            .map(|j| (0..n).map(|i| if i == j { 1.0 } else { 0.0 }).collect())
            .collect();
        let ds = SurvivalDataset::new(
            Matrix::from_columns(&cols),
            (0..n).map(|i| (n - i) as f64).collect(),
            vec![true; n],
            "i",
        );
        let pr = CoxProblem::new(&ds);
        let w = vec![1.0; n];
        let z: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let b = wls_coordinate_descent(
            &pr,
            &w,
            &z,
            &vec![0.0; n],
            Objective { l1: 0.0, l2: 0.5 },
            100,
            1e-12,
        );
        // Identity design after sorting still selects one z per column,
        // shrunk by 1/(1+2λ2) = 1/2.
        let eta = pr.x.matvec(&b);
        for k in 0..n {
            assert!((eta[k] - z[k] / 2.0).abs() < 1e-9, "{} vs {}", eta[k], z[k] / 2.0);
        }
    }

    #[test]
    fn reaches_same_optimum_as_surrogates() {
        let pr = random_problem(80, 4, 5);
        let cfg = FitConfig {
            objective: Objective { l1: 0.5, l2: 1.0 },
            max_iters: 200,
            tol: 1e-12,
            ..Default::default()
        };
        let rq = QuasiNewton::default().fit(&pr, &cfg).unwrap();
        let rc = CubicSurrogate
            .fit(&pr, &FitConfig { max_iters: 3000, tol: 1e-13, ..cfg.clone() })
            .unwrap();
        assert!(
            (rq.objective_value - rc.objective_value).abs() < 1e-4,
            "quasi-newton {} vs cubic {}",
            rq.objective_value,
            rc.objective_value
        );
    }

    #[test]
    fn fewer_outer_iterations_than_cd_sweeps() {
        // Quasi-Newton makes big outer steps; it should converge in far
        // fewer outer iterations than plain quadratic CD sweeps.
        let pr = random_problem(100, 5, 6);
        let cfg = FitConfig {
            objective: Objective { l1: 0.0, l2: 1.0 },
            max_iters: 500,
            tol: 1e-11,
            ..Default::default()
        };
        let rq = QuasiNewton::default().fit(&pr, &cfg).unwrap();
        let rcd = QuadraticSurrogate.fit(&pr, &cfg).unwrap();
        assert!(rq.iterations < rcd.iterations, "{} vs {}", rq.iterations, rcd.iterations);
    }
}
