//! Coordinate descent on the cubic surrogate (Eq. 16 / 18 / 22) — the
//! paper's second-order method.
//!
//! Per coordinate: one fused O(n) pass for (d1, d2) — Corollary 3.3 makes
//! the *exact* second derivative as cheap as the gradient — then the
//! analytic cubic-regularized Newton step with the explicit constant L3
//! from Theorem 3.4. Monotone descent, no line search.

use super::cd::{fit_support_with, SurrogateKind};
use super::objective::{engine_cd_fit, FitConfig, FitResult, Objective, Optimizer};
use super::prox::{cubic_l1_step, cubic_step};
use crate::cox::derivatives::{coord_d1_d2_ws_b, Workspace};
use crate::cox::lipschitz::{all_lipschitz, LipschitzPair};
use crate::cox::{CoxProblem, CoxState};
use crate::error::Result;
use crate::runtime::engine::CoxEngine;
use crate::util::compute::{default_backend, KernelBackend};

/// The paper's second-order surrogate method.
#[derive(Clone, Copy, Debug, Default)]
pub struct CubicSurrogate;

/// One cubic-surrogate coordinate step; returns the applied Δ.
/// ℓ2 absorbs into the first/second derivatives (footnote 2); L3 is
/// unchanged (the ridge term has zero third derivative).
#[inline]
pub fn cubic_coord_step(
    problem: &CoxProblem,
    state: &mut CoxState,
    l: usize,
    lip: LipschitzPair,
    obj: Objective,
) -> f64 {
    cubic_coord_step_ws(problem, state, &mut Workspace::default(), l, lip, obj)
}

/// [`cubic_coord_step`] through a shared [`Workspace`]: steps that leave
/// η untouched reuse the cached risk-set weights (division-free fused
/// pass) instead of re-accumulating the S0 prefix.
#[inline]
pub fn cubic_coord_step_ws(
    problem: &CoxProblem,
    state: &mut CoxState,
    ws: &mut Workspace,
    l: usize,
    lip: LipschitzPair,
    obj: Objective,
) -> f64 {
    cubic_coord_step_ws_b(problem, state, ws, l, lip, obj, default_backend())
}

/// [`cubic_coord_step_ws`] with an explicit kernel backend threaded into
/// both the derivative pass and the incremental η/w update.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn cubic_coord_step_ws_b(
    problem: &CoxProblem,
    state: &mut CoxState,
    ws: &mut Workspace,
    l: usize,
    lip: LipschitzPair,
    obj: Objective,
    backend: KernelBackend,
) -> f64 {
    let (d1, d2) = coord_d1_d2_ws_b(problem, state, ws, l, backend);
    let a = d1 + 2.0 * obj.l2 * state.beta[l];
    let b = d2 + 2.0 * obj.l2;
    if b <= 0.0 && lip.l3 <= 0.0 {
        return 0.0;
    }
    let delta = if obj.l1 > 0.0 {
        cubic_l1_step(a, b, lip.l3, state.beta[l], obj.l1)
    } else {
        cubic_step(a, b, lip.l3)
    };
    state.update_coord_col_b(backend, problem.x.col(l), problem.col_binary[l], l, delta);
    delta
}

/// Run cubic-surrogate CD sweeps over `coords` until `config` stops.
/// Thin wrapper over the shared support-restricted routine in
/// [`super::cd`] — the sweep loop lives there once for both surrogates.
pub fn fit_support(
    problem: &CoxProblem,
    state: CoxState,
    coords: &[usize],
    config: &FitConfig,
    lip: &[LipschitzPair],
) -> FitResult {
    fit_support_with(problem, state, coords, config, lip, SurrogateKind::Cubic)
}

impl Optimizer for CubicSurrogate {
    fn name(&self) -> &'static str {
        "cubic-surrogate"
    }

    fn fit_from(
        &self,
        problem: &CoxProblem,
        state: CoxState,
        config: &FitConfig,
        engine: &dyn CoxEngine,
    ) -> Result<FitResult> {
        if engine.is_native() {
            // Fused in-process kernels — the paper's hot path.
            let lip = all_lipschitz(problem);
            let coords: Vec<usize> = (0..problem.p()).collect();
            return Ok(fit_support(problem, state, &coords, config, &lip));
        }
        // Engine-served quantities: the identical sweep runs on the AOT
        // XLA artifacts, proving the three layers compose on a real fit.
        let obj = config.objective;
        engine_cd_fit(problem, state, config, engine, |engine, problem, state, l, lip| {
            let (d1, d2) = engine.coord_d1_d2(problem, state, l)?;
            let a = d1 + 2.0 * obj.l2 * state.beta[l];
            let b = (d2 + 2.0 * obj.l2).max(0.0);
            if b <= 0.0 && lip.l3 <= 0.0 {
                return Ok(());
            }
            let delta = if obj.l1 > 0.0 {
                cubic_l1_step(a, b, lip.l3, state.beta[l], obj.l1)
            } else {
                cubic_step(a, b, lip.l3)
            };
            state.update_coord(problem, l, delta);
            Ok(())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cox::derivatives::beta_gradient;
    use crate::util::rng::Rng;

    use crate::data::SurvivalDataset;
    use crate::linalg::Matrix;

    fn random_problem(n: usize, p: usize, seed: u64) -> CoxProblem {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f64>> =
            (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let time: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.5, 9.5)).collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.7)).collect();
        CoxProblem::new(&SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "r"))
    }

    #[test]
    fn monotone_decrease() {
        let pr = random_problem(60, 5, 21);
        let cfg = FitConfig { max_iters: 50, ..Default::default() };
        let res = CubicSurrogate.fit(&pr, &cfg).unwrap();
        assert!(res.trace.monotone(1e-10));
    }

    #[test]
    fn matches_quadratic_optimum_with_l2() {
        // Both surrogates minimize the same strictly convex objective, so
        // the final losses must agree.
        let pr = random_problem(70, 4, 22);
        let cfg = FitConfig {
            objective: Objective { l1: 0.0, l2: 1.0 },
            max_iters: 1000,
            tol: 1e-13,
            ..Default::default()
        };
        let rq = super::super::QuadraticSurrogate.fit(&pr, &cfg).unwrap();
        let rc = CubicSurrogate.fit(&pr, &cfg).unwrap();
        assert!(
            (rq.objective_value - rc.objective_value).abs() < 1e-5,
            "quad {} vs cubic {}",
            rq.objective_value,
            rc.objective_value
        );
    }

    #[test]
    fn converges_faster_than_quadratic_per_iteration() {
        // The cubic surrogate uses the exact local curvature, so after the
        // same (small) number of sweeps its loss should not be worse.
        let pr = random_problem(90, 5, 23);
        let cfg = FitConfig {
            objective: Objective { l1: 0.0, l2: 1.0 },
            max_iters: 4,
            tol: 0.0,
            ..Default::default()
        };
        let rq = super::super::QuadraticSurrogate.fit(&pr, &cfg).unwrap();
        let rc = CubicSurrogate.fit(&pr, &cfg).unwrap();
        assert!(
            rc.objective_value <= rq.objective_value + 1e-9,
            "cubic {} should be <= quad {} after 4 sweeps",
            rc.objective_value,
            rq.objective_value
        );
    }

    #[test]
    fn stationarity_with_l2() {
        let pr = random_problem(80, 4, 24);
        let cfg = FitConfig {
            objective: Objective { l1: 0.0, l2: 2.0 },
            max_iters: 500,
            tol: 1e-13,
            ..Default::default()
        };
        let res = CubicSurrogate.fit(&pr, &cfg).unwrap();
        let st = CoxState::from_beta(&pr, &res.beta);
        let g = beta_gradient(&pr, &st);
        for l in 0..pr.p() {
            let pg = g[l] + 4.0 * res.beta[l];
            assert!(pg.abs() < 1e-4, "coord {l}: {pg}");
        }
    }

    #[test]
    fn l1_sparsity_and_monotonicity() {
        let pr = random_problem(100, 8, 25);
        let cfg = FitConfig {
            objective: Objective { l1: 5.0, l2: 1.0 },
            max_iters: 100,
            ..Default::default()
        };
        let res = CubicSurrogate.fit(&pr, &cfg).unwrap();
        assert!(res.trace.monotone(1e-9));
        let nnz = res.beta.iter().filter(|b| b.abs() > 1e-10).count();
        assert!(nnz < pr.p(), "λ1 should zero out some coordinates");
    }
}
