//! Proximal-Newton baseline (Section 2, method 3) — the skglm Cox datafit.
//!
//! Replaces the η-space Hessian by the diagonal upper bound
//! `H(η) = diag(∇_η ℓ(η) + δ)`; since `[∇_η ℓ]_k = w_k·A_k − δ_k`, that
//! diagonal is `w_k·A_k`, the positive part of the true diagonal (the
//! subtracted `w_k²·B_k` term is dropped). The WLS subproblem is then
//! solved by coordinate descent exactly as in quasi-Newton.

use super::objective::{require_native, FitConfig, FitResult, Optimizer, Stopper};
use super::quasi_newton::wls_coordinate_descent;
use crate::cox::derivatives::eta_gradient;
use crate::cox::{CoxProblem, CoxState};
use crate::error::Result;
use crate::runtime::engine::CoxEngine;

/// skglm-style proximal Newton with the diagonal bound.
#[derive(Clone, Copy, Debug)]
pub struct ProxNewton {
    pub inner_sweeps: usize,
    pub inner_tol: f64,
    pub weight_floor: f64,
}

impl Default for ProxNewton {
    fn default() -> Self {
        ProxNewton { inner_sweeps: 50, inner_tol: 1e-8, weight_floor: 1e-10 }
    }
}

impl Optimizer for ProxNewton {
    fn name(&self) -> &'static str {
        "prox-newton"
    }

    fn fit_from(
        &self,
        problem: &CoxProblem,
        mut state: CoxState,
        config: &FitConfig,
        engine: &dyn CoxEngine,
    ) -> Result<FitResult> {
        require_native(self.name(), engine)?;
        let obj = config.objective;
        let mut stopper = Stopper::new();
        let mut iters = 0;
        for it in 0..config.max_iters {
            let u = eta_gradient(problem, &state);
            // Diagonal bound: grad + δ = w_k A_k ≥ 0.
            let mut w: Vec<f64> = (0..problem.n()).map(|k| u[k] + problem.delta[k]).collect();
            let z: Vec<f64> = (0..problem.n())
                .map(|k| {
                    if w[k] < self.weight_floor {
                        w[k] = self.weight_floor;
                    }
                    state.eta[k] - u[k] / w[k]
                })
                .collect();
            let new_beta = wls_coordinate_descent(
                problem,
                &w,
                &z,
                &state.beta,
                obj,
                self.inner_sweeps,
                self.inner_tol,
            );
            state.set_beta(problem, &new_beta);
            iters = it + 1;
            let loss = obj.value(problem, &state);
            if stopper.step(it, loss, config) {
                break;
            }
        }
        let objective_value = obj.value(problem, &state);
        Ok(FitResult { beta: state.beta, trace: stopper.trace, objective_value, iterations: iters })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SurvivalDataset;
    use crate::linalg::Matrix;
    use crate::optim::objective::Objective;
    use crate::optim::CubicSurrogate;
    use crate::util::rng::Rng;

    fn random_problem(n: usize, p: usize, seed: u64) -> CoxProblem {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f64>> =
            (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let time: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.5, 9.5)).collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.7)).collect();
        CoxProblem::new(&SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "r"))
    }

    #[test]
    fn weights_are_nonnegative_bound() {
        // The diag bound w_k·A_k must dominate the true diagonal.
        use crate::cox::derivatives::eta_hessian_diag;
        let pr = random_problem(40, 3, 9);
        let st = CoxState::from_beta(&pr, &[0.3, -0.2, 0.1]);
        let u = eta_gradient(&pr, &st);
        let diag = eta_hessian_diag(&pr, &st);
        for k in 0..pr.n() {
            let bound = u[k] + pr.delta[k];
            assert!(bound >= -1e-12, "bound must be >= 0");
            assert!(bound + 1e-10 >= diag[k], "bound {bound} < diag {}", diag[k]);
        }
    }

    #[test]
    fn reaches_same_optimum_with_l1_l2() {
        let pr = random_problem(80, 4, 10);
        let cfg = FitConfig {
            objective: Objective { l1: 1.0, l2: 1.0 },
            max_iters: 400,
            tol: 1e-12,
            ..Default::default()
        };
        let rp = ProxNewton::default().fit(&pr, &cfg).unwrap();
        let rc = CubicSurrogate
            .fit(&pr, &FitConfig { max_iters: 3000, tol: 1e-13, ..cfg.clone() })
            .unwrap();
        assert!(
            (rp.objective_value - rc.objective_value).abs() < 1e-4,
            "prox-newton {} vs cubic {}",
            rp.objective_value,
            rc.objective_value
        );
    }

    #[test]
    fn slower_per_iteration_progress_than_quasi_newton() {
        // The diagonal *bound* is looser than the true diagonal, so after
        // one outer iteration prox-Newton should not be ahead.
        let pr = random_problem(100, 5, 11);
        let cfg = FitConfig {
            objective: Objective { l1: 0.0, l2: 1.0 },
            max_iters: 1,
            tol: 0.0,
            ..Default::default()
        };
        let rp = ProxNewton::default().fit(&pr, &cfg).unwrap();
        let rq = crate::optim::QuasiNewton::default().fit(&pr, &cfg).unwrap();
        assert!(
            rp.objective_value >= rq.objective_value - 1e-6,
            "prox {} vs quasi {}",
            rp.objective_value,
            rq.objective_value
        );
    }
}
