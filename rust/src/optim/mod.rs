//! Optimizers for the (regularized) CPH problem.
//!
//! The paper's methods — coordinate descent on the **quadratic** (Eq. 15)
//! and **cubic** (Eq. 16) surrogate functions — plus every baseline from
//! Section 2: exact Newton, quasi Newton (Simon et al. / glmnet-style),
//! proximal Newton (skglm-style diagonal bound), and gradient descent.
//!
//! All optimizers implement [`Optimizer::fit`] and record a [`Trace`] of
//! (iteration, wall-clock, loss) so the Figure-1 experiments can plot
//! loss vs. iterations and loss vs. time for every method uniformly.
//! Engine selection (native kernels vs. the AOT-XLA artifacts) threads
//! through [`Optimizer::fit_from`]; [`OptimizerKind`] is the typed
//! registry of methods (re-exported by [`crate::api`]).

pub mod cd;
pub mod cubic;
pub mod gradient_descent;
pub mod newton;
pub mod nonconvex;
pub mod objective;
pub mod prox;
pub mod prox_newton;
pub mod quadratic;
pub mod quasi_newton;

pub use cd::{fit_support_warm, fit_support_with, SurrogateKind};
pub use cubic::CubicSurrogate;
pub use gradient_descent::GradientDescent;
pub use newton::ExactNewton;
pub use objective::{FitConfig, FitResult, Objective, Optimizer, Trace};
pub use prox_newton::ProxNewton;
pub use quadratic::QuadraticSurrogate;
pub use quasi_newton::QuasiNewton;

use crate::error::{FastSurvivalError, Result};

/// Typed enumeration of every optimizer — the one registry behind both
/// [`by_name`] (CLI strings) and the `CoxFit` builder.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    /// Quadratic-surrogate coordinate descent (paper Eq. 15).
    Quadratic,
    /// Cubic-surrogate coordinate descent (paper Eq. 16) — the default.
    Cubic,
    /// Exact Newton (Section 2 baseline; no ℓ1, native engine only).
    Newton,
    /// Exact Newton with Armijo backtracking.
    NewtonLineSearch,
    /// glmnet-style quasi-Newton (Simon et al.).
    QuasiNewton,
    /// skglm-style proximal Newton with the diagonal bound.
    ProxNewton,
    /// (Proximal) gradient descent with the safe 1/L step.
    GradientDescent,
}

impl OptimizerKind {
    pub const ALL: [OptimizerKind; 7] = [
        OptimizerKind::Quadratic,
        OptimizerKind::Cubic,
        OptimizerKind::Newton,
        OptimizerKind::NewtonLineSearch,
        OptimizerKind::QuasiNewton,
        OptimizerKind::ProxNewton,
        OptimizerKind::GradientDescent,
    ];

    /// CLI name (the same strings [`by_name`] always accepted).
    pub fn name(self) -> &'static str {
        match self {
            OptimizerKind::Quadratic => "quadratic",
            OptimizerKind::Cubic => "cubic",
            OptimizerKind::Newton => "newton",
            OptimizerKind::NewtonLineSearch => "newton-ls",
            OptimizerKind::QuasiNewton => "quasi-newton",
            OptimizerKind::ProxNewton => "prox-newton",
            OptimizerKind::GradientDescent => "gd",
        }
    }

    pub fn from_name(name: &str) -> Result<Self> {
        OptimizerKind::ALL
            .into_iter()
            .find(|k| k.name() == name)
            .ok_or_else(|| FastSurvivalError::Unknown {
                kind: "optimizer",
                name: name.to_string(),
                expected: "quadratic|cubic|newton|newton-ls|quasi-newton|prox-newton|gd",
            })
    }

    /// Instantiate the optimizer.
    pub fn build(self) -> Box<dyn Optimizer> {
        match self {
            OptimizerKind::Quadratic => Box::new(QuadraticSurrogate),
            OptimizerKind::Cubic => Box::new(CubicSurrogate),
            OptimizerKind::Newton => Box::new(ExactNewton::default()),
            OptimizerKind::NewtonLineSearch => Box::new(ExactNewton { line_search: true }),
            OptimizerKind::QuasiNewton => Box::new(QuasiNewton::default()),
            OptimizerKind::ProxNewton => Box::new(ProxNewton::default()),
            OptimizerKind::GradientDescent => Box::new(GradientDescent::default()),
        }
    }

    /// The surrogate CD methods run on any engine; the Newton-family and
    /// GD baselines need the native full-gradient/Hessian kernels.
    pub fn engine_generic(self) -> bool {
        matches!(self, OptimizerKind::Quadratic | OptimizerKind::Cubic)
    }

    /// Exact Newton has no ℓ1 (non-smooth) mode.
    pub fn supports_l1(self) -> bool {
        !matches!(self, OptimizerKind::Newton | OptimizerKind::NewtonLineSearch)
    }
}

/// Construct an optimizer by name (CLI / experiment harness). Unknown
/// names return a typed [`FastSurvivalError::Unknown`].
pub fn by_name(name: &str) -> Result<Box<dyn Optimizer>> {
    Ok(OptimizerKind::from_name(name)?.build())
}

/// Names usable with [`by_name`].
pub const ALL_OPTIMIZERS: [&str; 6] =
    ["quadratic", "cubic", "newton", "quasi-newton", "prox-newton", "gd"];
