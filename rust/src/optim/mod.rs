//! Optimizers for the (regularized) CPH problem.
//!
//! The paper's methods — coordinate descent on the **quadratic** (Eq. 15)
//! and **cubic** (Eq. 16) surrogate functions — plus every baseline from
//! Section 2: exact Newton, quasi Newton (Simon et al. / glmnet-style),
//! proximal Newton (skglm-style diagonal bound), and gradient descent.
//!
//! All optimizers implement [`Optimizer::fit`] and record a [`Trace`] of
//! (iteration, wall-clock, loss) so the Figure-1 experiments can plot
//! loss vs. iterations and loss vs. time for every method uniformly.

pub mod cubic;
pub mod gradient_descent;
pub mod newton;
pub mod nonconvex;
pub mod objective;
pub mod prox;
pub mod prox_newton;
pub mod quadratic;
pub mod quasi_newton;

pub use cubic::CubicSurrogate;
pub use gradient_descent::GradientDescent;
pub use newton::ExactNewton;
pub use objective::{FitConfig, FitResult, Objective, Optimizer, Trace};
pub use prox_newton::ProxNewton;
pub use quadratic::QuadraticSurrogate;
pub use quasi_newton::QuasiNewton;

/// Construct an optimizer by name (CLI / experiment harness).
pub fn by_name(name: &str) -> Box<dyn Optimizer> {
    match name {
        "quadratic" => Box::new(QuadraticSurrogate::default()),
        "cubic" => Box::new(CubicSurrogate::default()),
        "newton" => Box::new(ExactNewton::default()),
        "newton-ls" => Box::new(ExactNewton { line_search: true }),
        "quasi-newton" => Box::new(QuasiNewton::default()),
        "prox-newton" => Box::new(ProxNewton::default()),
        "gd" => Box::new(GradientDescent::default()),
        other => panic!("unknown optimizer {other:?}"),
    }
}

/// Names usable with [`by_name`].
pub const ALL_OPTIMIZERS: [&str; 6] =
    ["quadratic", "cubic", "newton", "quasi-newton", "prox-newton", "gd"];
