//! The one support-restricted coordinate-descent routine behind both
//! surrogates.
//!
//! `quadratic::fit_support` and `cubic::fit_support` used to carry
//! near-identical sweep loops; they now both delegate here, parameterized
//! by [`SurrogateKind`] — the per-coordinate step is the only thing that
//! differs between the paper's first- and second-order methods. The
//! warm-capable entry point ([`fit_support_warm`]) mutates a caller-owned
//! [`CoxState`] and reuses a caller-owned [`Workspace`], which is what
//! the path solver and ABESS splicing need: a fit that starts where the
//! previous one ended instead of re-deriving everything from zeros.

use super::cubic::cubic_coord_step_ws_b;
use super::objective::{FitConfig, FitResult, Stopper};
use super::prox::{cubic_l1_step, cubic_step, quad_l1_step, quad_step};
use super::quadratic::quad_coord_step_ws_b;
use super::Objective;
use crate::cox::derivatives::{
    coord_d1_col_b, coord_d1_d2_col_b, coord_d1_d2_col_merged_b, coord_d1_d2_ws_b, coord_d1_ws_b,
    MergeScratch, Workspace,
};
use crate::cox::lipschitz::LipschitzPair;
use crate::cox::problem::TieGroup;
use crate::cox::{CoxProblem, CoxState};
use crate::util::compute::{default_backend, KernelBackend};

/// Steps whose magnitude is below `STEP_SNAP · (1 + |β_l|)` are treated
/// as exact no-ops by [`SurrogateKind::step_residual`]: a converged
/// coordinate then leaves η (and the version-tagged risk-set cache)
/// untouched instead of paying a full exp-update for a numerically
/// meaningless move. Far below any stopping tolerance in use.
const STEP_SNAP: f64 = 1e-12;

/// Which surrogate supplies the per-coordinate analytic step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SurrogateKind {
    /// Quadratic surrogate (Eq. 15/17/20): explicit Lipschitz constant L2.
    Quadratic,
    /// Cubic surrogate (Eq. 16/18/22): exact d2 plus L3 — the default.
    Cubic,
}

impl SurrogateKind {
    pub fn name(self) -> &'static str {
        match self {
            SurrogateKind::Quadratic => "quadratic-surrogate",
            SurrogateKind::Cubic => "cubic-surrogate",
        }
    }

    /// One surrogate coordinate step through a shared workspace; returns
    /// the applied Δ.
    #[inline]
    pub fn step(
        self,
        problem: &CoxProblem,
        state: &mut CoxState,
        ws: &mut Workspace,
        l: usize,
        lip: LipschitzPair,
        obj: Objective,
    ) -> f64 {
        self.step_b(problem, state, ws, l, lip, obj, default_backend())
    }

    /// [`SurrogateKind::step`] with an explicit kernel backend.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub fn step_b(
        self,
        problem: &CoxProblem,
        state: &mut CoxState,
        ws: &mut Workspace,
        l: usize,
        lip: LipschitzPair,
        obj: Objective,
        backend: KernelBackend,
    ) -> f64 {
        match self {
            SurrogateKind::Quadratic => {
                quad_coord_step_ws_b(problem, state, ws, l, lip, obj, backend)
            }
            SurrogateKind::Cubic => {
                cubic_coord_step_ws_b(problem, state, ws, l, lip, obj, backend)
            }
        }
    }

    /// One surrogate coordinate step that also reports the coordinate's
    /// KKT residual, measured *before* the step from the same derivative
    /// pass (no extra work):
    /// `|∇_l + λ1·sign(β_l)|` for active coordinates,
    /// `max(|∇_l| − λ1, 0)` for zero ones, with the ℓ2 term folded into
    /// ∇_l. A coordinate whose residual is already ≤ `skip_below` is
    /// left untouched — it is converged to the caller's tolerance, so
    /// stepping it is pure polish that would dirty the risk-set cache.
    /// Negligible steps (below [`STEP_SNAP`]) are likewise snapped to
    /// exact no-ops. Returns `(applied Δ, residual)`. The path solver's
    /// inner loop stops on `max residual ≤ ε`, which bounds the loss
    /// suboptimality quadratically — the basis of the warm-vs-cold
    /// endpoint guarantee.
    #[allow(clippy::too_many_arguments)]
    pub fn step_residual(
        self,
        problem: &CoxProblem,
        state: &mut CoxState,
        ws: &mut Workspace,
        l: usize,
        lip: LipschitzPair,
        obj: Objective,
        skip_below: f64,
    ) -> (f64, f64) {
        self.step_residual_b(problem, state, ws, l, lip, obj, skip_below, default_backend())
    }

    /// [`SurrogateKind::step_residual`] with an explicit kernel backend.
    #[allow(clippy::too_many_arguments)]
    pub fn step_residual_b(
        self,
        problem: &CoxProblem,
        state: &mut CoxState,
        ws: &mut Workspace,
        l: usize,
        lip: LipschitzPair,
        obj: Objective,
        skip_below: f64,
        backend: KernelBackend,
    ) -> (f64, f64) {
        let beta_l = state.beta[l];
        let (a, b) = match self {
            SurrogateKind::Quadratic => {
                let b = lip.l2 + 2.0 * obj.l2;
                if b <= 0.0 {
                    // Flat (constant) coordinate: no information, no move.
                    return (0.0, 0.0);
                }
                let d1 = coord_d1_ws_b(problem, state, ws, l, backend);
                (d1 + 2.0 * obj.l2 * beta_l, b)
            }
            SurrogateKind::Cubic => {
                let (d1, d2) = coord_d1_d2_ws_b(problem, state, ws, l, backend);
                (d1 + 2.0 * obj.l2 * beta_l, d2 + 2.0 * obj.l2)
            }
        };
        let residual = if beta_l != 0.0 {
            (a + obj.l1 * beta_l.signum()).abs()
        } else {
            (a.abs() - obj.l1).max(0.0)
        };
        if residual <= skip_below {
            return (0.0, residual);
        }
        let delta = match self {
            SurrogateKind::Quadratic => {
                if obj.l1 > 0.0 {
                    quad_l1_step(a, b, beta_l, obj.l1)
                } else {
                    quad_step(a, b)
                }
            }
            SurrogateKind::Cubic => {
                if b <= 0.0 && lip.l3 <= 0.0 {
                    0.0
                } else if obj.l1 > 0.0 {
                    cubic_l1_step(a, b, lip.l3, beta_l, obj.l1)
                } else {
                    cubic_step(a, b, lip.l3)
                }
            }
        };
        let delta = if delta.abs() <= STEP_SNAP * (1.0 + beta_l.abs()) { 0.0 } else { delta };
        state.update_coord_col_b(backend, problem.x.col(l), problem.col_binary[l], l, delta);
        (delta, residual)
    }

    /// Parts-level sibling of [`SurrogateKind::step_residual`]: the same
    /// derivative assembly, KKT-residual formula, prox dispatch, and
    /// [`STEP_SNAP`] no-op snapping, fed from an explicit column slice
    /// plus risk-set parts instead of a [`CoxProblem`]/[`Workspace`] —
    /// the out-of-core driver's per-coordinate step. Living here (and
    /// delegating to the same prox and parts-kernels) keeps one source
    /// of truth: an edit to the engine's step semantics cannot silently
    /// diverge the chunked fit. Derivatives always take the classic
    /// fused pass (there is no η-version cache without a workspace),
    /// which is bit-identical to a fresh-workspace
    /// [`SurrogateKind::step_residual`] call.
    #[allow(clippy::too_many_arguments)]
    pub fn step_residual_col(
        self,
        groups: &[TieGroup],
        xt_delta_l: f64,
        state: &mut CoxState,
        col: &[f64],
        binary: bool,
        l: usize,
        lip: LipschitzPair,
        obj: Objective,
        skip_below: f64,
    ) -> (f64, f64) {
        self.step_residual_col_b(
            groups,
            xt_delta_l,
            state,
            col,
            binary,
            l,
            lip,
            obj,
            skip_below,
            default_backend(),
        )
    }

    /// [`SurrogateKind::step_residual_col`] with an explicit kernel
    /// backend.
    #[allow(clippy::too_many_arguments)]
    pub fn step_residual_col_b(
        self,
        groups: &[TieGroup],
        xt_delta_l: f64,
        state: &mut CoxState,
        col: &[f64],
        binary: bool,
        l: usize,
        lip: LipschitzPair,
        obj: Objective,
        skip_below: f64,
        backend: KernelBackend,
    ) -> (f64, f64) {
        let beta_l = state.beta[l];
        if self == SurrogateKind::Quadratic && lip.l2 + 2.0 * obj.l2 <= 0.0 {
            // Flat (constant) coordinate: no information, no move.
            return (0.0, 0.0);
        }
        let (d1, d2) = match self {
            SurrogateKind::Quadratic => {
                (coord_d1_col_b(backend, groups, &state.w, col, xt_delta_l), 0.0)
            }
            SurrogateKind::Cubic => coord_d1_d2_col_b(backend, groups, &state.w, col, xt_delta_l),
        };
        let (delta, residual) = self.delta_residual_from(d1, d2, beta_l, lip, obj, skip_below);
        state.update_coord_col_b(backend, col, binary, l, delta);
        (delta, residual)
    }

    /// Tiled-merge sibling of [`SurrogateKind::step_residual_col_b`]:
    /// derivatives come from the canonical tile decomposition
    /// ([`coord_d1_d2_col_merged_b`]) instead of the flat fused pass, so
    /// a fit stepping through here is bitwise reproducible no matter how
    /// the tiles are later fanned out across shard workers — the
    /// single-store chunked fit and the sharded engine both route their
    /// per-coordinate step through this entry (or its distributed
    /// equivalent, [`SurrogateKind::delta_residual_from`] over the same
    /// tile partials), which is what makes sharded-vs-single parity a
    /// bitwise identity rather than a tolerance.
    #[allow(clippy::too_many_arguments)]
    pub fn step_residual_col_merged_b(
        self,
        groups: &[TieGroup],
        tile_cuts: &[usize],
        scratch: &mut MergeScratch,
        xt_delta_l: f64,
        state: &mut CoxState,
        col: &[f64],
        binary: bool,
        l: usize,
        lip: LipschitzPair,
        obj: Objective,
        skip_below: f64,
        backend: KernelBackend,
    ) -> (f64, f64) {
        let beta_l = state.beta[l];
        if self == SurrogateKind::Quadratic && lip.l2 + 2.0 * obj.l2 <= 0.0 {
            // Flat (constant) coordinate: no information, no move.
            return (0.0, 0.0);
        }
        let need_d2 = self == SurrogateKind::Cubic;
        crate::obs::counters::kernel_calls(backend == KernelBackend::Simd, 1);
        let (d1, d2) = coord_d1_d2_col_merged_b(
            backend, groups, tile_cuts, &state.w, col, xt_delta_l, need_d2, scratch,
        );
        let (delta, residual) = self.delta_residual_from(d1, d2, beta_l, lip, obj, skip_below);
        state.update_coord_col_b(backend, col, binary, l, delta);
        (delta, residual)
    }

    /// The step semantics with the derivative pass and the η/w update
    /// externalized: from already-assembled `(d1, d2)` compute the
    /// applied Δ and the pre-step KKT residual. This is the single
    /// source of truth for the residual formula, the prox dispatch, and
    /// the [`STEP_SNAP`] no-op snap — the column-level steps above feed
    /// it from their own derivative passes, and the sharded engine feeds
    /// it from tile partials merged across workers (applying Δ on the
    /// workers that own the η/w slices). `d2` is ignored for the
    /// quadratic surrogate, whose curvature is the explicit `lip.l2`.
    pub(crate) fn delta_residual_from(
        self,
        d1: f64,
        d2: f64,
        beta_l: f64,
        lip: LipschitzPair,
        obj: Objective,
        skip_below: f64,
    ) -> (f64, f64) {
        let (a, b) = match self {
            SurrogateKind::Quadratic => {
                let b = lip.l2 + 2.0 * obj.l2;
                if b <= 0.0 {
                    // Flat (constant) coordinate: no information, no move.
                    return (0.0, 0.0);
                }
                (d1 + 2.0 * obj.l2 * beta_l, b)
            }
            SurrogateKind::Cubic => (d1 + 2.0 * obj.l2 * beta_l, d2 + 2.0 * obj.l2),
        };
        let residual = if beta_l != 0.0 {
            (a + obj.l1 * beta_l.signum()).abs()
        } else {
            (a.abs() - obj.l1).max(0.0)
        };
        if residual <= skip_below {
            return (0.0, residual);
        }
        let delta = match self {
            SurrogateKind::Quadratic => {
                if obj.l1 > 0.0 {
                    quad_l1_step(a, b, beta_l, obj.l1)
                } else {
                    quad_step(a, b)
                }
            }
            SurrogateKind::Cubic => {
                if b <= 0.0 && lip.l3 <= 0.0 {
                    0.0
                } else if obj.l1 > 0.0 {
                    cubic_l1_step(a, b, lip.l3, beta_l, obj.l1)
                } else {
                    cubic_step(a, b, lip.l3)
                }
            }
        };
        let delta = if delta.abs() <= STEP_SNAP * (1.0 + beta_l.abs()) { 0.0 } else { delta };
        (delta, residual)
    }
}

/// Run surrogate CD sweeps over `coords` until `config` stops, mutating
/// `state` in place (warm start in, warm state out) and reusing `ws`
/// across sweeps — and, through the version-tagged cache, across calls.
/// Returns the fit bookkeeping; `state` holds the final coefficients.
pub fn fit_support_warm(
    problem: &CoxProblem,
    state: &mut CoxState,
    coords: &[usize],
    config: &FitConfig,
    lip: &[LipschitzPair],
    kind: SurrogateKind,
    ws: &mut Workspace,
) -> FitResult {
    let obj = config.objective;
    // The backend was resolved once when the config was built; optimizer
    // loops never consult the environment.
    let backend = config.compute.backend;
    let mut stopper = Stopper::new();
    let mut iters = 0;
    for it in 0..config.max_iters {
        let _span = crate::obs::SpanTimer::start(crate::obs::Phase::CdSweep);
        for &l in coords {
            kind.step_b(problem, state, ws, l, lip[l], obj, backend);
        }
        iters = it + 1;
        let loss = obj.value(problem, state);
        if stopper.step(it, loss, config) {
            break;
        }
    }
    let objective_value = obj.value(problem, state);
    FitResult {
        beta: state.beta.clone(),
        trace: stopper.trace,
        objective_value,
        iterations: iters,
    }
}

/// [`fit_support_warm`] for callers that hand over the state and only
/// want the result — the shape `quadratic::fit_support` and
/// `cubic::fit_support` have always had.
pub fn fit_support_with(
    problem: &CoxProblem,
    mut state: CoxState,
    coords: &[usize],
    config: &FitConfig,
    lip: &[LipschitzPair],
    kind: SurrogateKind,
) -> FitResult {
    let mut ws = Workspace::default();
    let mut res = fit_support_warm(problem, &mut state, coords, config, lip, kind, &mut ws);
    // The caller owns neither state nor workspace: move β out instead of
    // cloning it a second time.
    res.beta = state.beta;
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cox::lipschitz::all_lipschitz;
    use crate::data::SurvivalDataset;
    use crate::linalg::Matrix;
    use crate::util::rng::Rng;

    fn random_problem(n: usize, p: usize, seed: u64) -> CoxProblem {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f64>> =
            (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let time: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.5, 9.5)).collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.7)).collect();
        CoxProblem::new(&SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "r"))
    }

    #[test]
    fn both_surrogates_agree_on_the_strictly_convex_optimum() {
        let pr = random_problem(80, 5, 71);
        let lip = all_lipschitz(&pr);
        let coords: Vec<usize> = (0..pr.p()).collect();
        let cfg = FitConfig {
            objective: Objective { l1: 0.0, l2: 1.0 },
            max_iters: 2000,
            tol: 1e-13,
            ..Default::default()
        };
        let rq = fit_support_with(
            &pr,
            CoxState::zeros(&pr),
            &coords,
            &cfg,
            &lip,
            SurrogateKind::Quadratic,
        );
        let rc = fit_support_with(
            &pr,
            CoxState::zeros(&pr),
            &coords,
            &cfg,
            &lip,
            SurrogateKind::Cubic,
        );
        assert!(
            (rq.objective_value - rc.objective_value).abs() < 1e-6,
            "quad {} vs cubic {}",
            rq.objective_value,
            rc.objective_value
        );
    }

    #[test]
    fn warm_start_resumes_instead_of_restarting() {
        let pr = random_problem(100, 6, 72);
        let lip = all_lipschitz(&pr);
        let coords: Vec<usize> = (0..pr.p()).collect();
        let cfg = FitConfig {
            objective: Objective { l1: 0.0, l2: 0.5 },
            max_iters: 400,
            tol: 1e-13,
            ..Default::default()
        };
        let mut ws = Workspace::default();
        let mut state = CoxState::zeros(&pr);
        let first =
            fit_support_warm(&pr, &mut state, &coords, &cfg, &lip, SurrogateKind::Cubic, &mut ws);
        // Resuming at the optimum must converge immediately (a couple of
        // no-op sweeps) and not move the objective.
        let resumed =
            fit_support_warm(&pr, &mut state, &coords, &cfg, &lip, SurrogateKind::Cubic, &mut ws);
        assert!(resumed.iterations <= 3, "warm resume took {} sweeps", resumed.iterations);
        assert!((resumed.objective_value - first.objective_value).abs() < 1e-8);
    }

    #[test]
    fn residual_vanishes_at_the_optimum_and_matches_kkt() {
        let pr = random_problem(90, 5, 74);
        let lip = all_lipschitz(&pr);
        let coords: Vec<usize> = (0..pr.p()).collect();
        let obj = Objective { l1: 1.5, l2: 0.2 };
        let cfg = FitConfig { objective: obj, max_iters: 3000, tol: 1e-14, ..Default::default() };
        let mut ws = Workspace::default();
        let mut state = CoxState::zeros(&pr);
        fit_support_warm(&pr, &mut state, &coords, &cfg, &lip, SurrogateKind::Cubic, &mut ws);
        // At the converged point every coordinate's reported residual is
        // tiny and no step moves anything materially.
        for &l in &coords {
            let (delta, res) = SurrogateKind::Cubic
                .step_residual(&pr, &mut state, &mut ws, l, lip[l], obj, 0.0);
            assert!(res < 1e-3, "coord {l}: residual {res}");
            assert!(delta.abs() < 1e-3, "coord {l}: step {delta}");
        }
        // A large skip threshold turns every step into a reported no-op.
        let before = state.beta.clone();
        for &l in &coords {
            let (delta, _) = SurrogateKind::Cubic
                .step_residual(&pr, &mut state, &mut ws, l, lip[l], obj, f64::INFINITY);
            assert_eq!(delta, 0.0);
        }
        assert_eq!(state.beta, before, "skip_below must leave the state untouched");
        // Away from the optimum the residual is large for some coordinate.
        let mut fresh = CoxState::zeros(&pr);
        let mut ws2 = Workspace::default();
        let max_res = (0..pr.p())
            .map(|l| {
                SurrogateKind::Cubic
                    .step_residual(&pr, &mut fresh, &mut ws2, l, lip[l], obj, 0.0)
                    .1
            })
            .fold(0.0_f64, f64::max);
        assert!(max_res > 1e-1, "zero state should violate KKT: {max_res}");
    }

    #[test]
    fn parts_level_step_matches_problem_level_step_bitwise() {
        // The out-of-core driver steps through step_residual_col; a
        // fresh-workspace step_residual takes the identical classic
        // derivative pass, so whole sweeps must agree bit for bit.
        let pr = random_problem(60, 5, 99);
        let lip = all_lipschitz(&pr);
        let obj = Objective { l1: 0.7, l2: 0.3 };
        for kind in [SurrogateKind::Quadratic, SurrogateKind::Cubic] {
            let mut sa = CoxState::zeros(&pr);
            let mut sb = CoxState::zeros(&pr);
            for _sweep in 0..4 {
                for l in 0..pr.p() {
                    let (da, ra) = kind.step_residual(
                        &pr,
                        &mut sa,
                        &mut Workspace::default(),
                        l,
                        lip[l],
                        obj,
                        0.0,
                    );
                    let (db, rb) = kind.step_residual_col(
                        &pr.groups,
                        pr.xt_delta[l],
                        &mut sb,
                        pr.x.col(l),
                        pr.col_binary[l],
                        l,
                        lip[l],
                        obj,
                        0.0,
                    );
                    assert_eq!(da.to_bits(), db.to_bits(), "{kind:?} l={l}: Δ {da} vs {db}");
                    assert_eq!(ra.to_bits(), rb.to_bits(), "{kind:?} l={l}: r {ra} vs {rb}");
                }
            }
            assert_eq!(sa.beta, sb.beta);
            assert_eq!(sa.eta, sb.eta);
        }
    }

    #[test]
    fn merged_step_tracks_flat_step() {
        // The tiled-merge step reassociates the risk-set prefix sums
        // (tile subtotals + carries instead of one running fold), so it
        // is not bitwise against the flat column step — but whole
        // sweeps must agree to well under any stopping tolerance.
        use crate::cox::derivatives::{merge_tiles, MergeScratch};
        let pr = random_problem(300, 5, 104);
        let lip = all_lipschitz(&pr);
        let obj = Objective { l1: 0.4, l2: 0.2 };
        let cuts = merge_tiles(&pr.groups);
        let backend = default_backend();
        for kind in [SurrogateKind::Quadratic, SurrogateKind::Cubic] {
            let mut flat = CoxState::zeros(&pr);
            let mut merged = CoxState::zeros(&pr);
            let mut scratch = MergeScratch::default();
            for _sweep in 0..4 {
                for l in 0..pr.p() {
                    kind.step_residual_col_b(
                        &pr.groups,
                        pr.xt_delta[l],
                        &mut flat,
                        pr.x.col(l),
                        pr.col_binary[l],
                        l,
                        lip[l],
                        obj,
                        0.0,
                        backend,
                    );
                    let (dm, rm) = kind.step_residual_col_merged_b(
                        &pr.groups,
                        &cuts,
                        &mut scratch,
                        pr.xt_delta[l],
                        &mut merged,
                        pr.x.col(l),
                        pr.col_binary[l],
                        l,
                        lip[l],
                        obj,
                        0.0,
                        backend,
                    );
                    assert!(dm.is_finite() && rm.is_finite());
                }
            }
            for l in 0..pr.p() {
                assert!(
                    (flat.beta[l] - merged.beta[l]).abs() < 1e-8,
                    "{kind:?} l={l}: flat {} vs merged {}",
                    flat.beta[l],
                    merged.beta[l]
                );
            }
        }
    }

    #[test]
    fn restricted_support_stays_restricted() {
        let pr = random_problem(60, 6, 73);
        let lip = all_lipschitz(&pr);
        let cfg = FitConfig { max_iters: 30, ..Default::default() };
        for kind in [SurrogateKind::Quadratic, SurrogateKind::Cubic] {
            let res =
                fit_support_with(&pr, CoxState::zeros(&pr), &[0, 3], &cfg, &lip, kind);
            for (l, b) in res.beta.iter().enumerate() {
                if l != 0 && l != 3 {
                    assert_eq!(*b, 0.0, "{kind:?} moved off-support coord {l}");
                }
            }
        }
    }
}
