//! Exact Newton baseline (Section 2, method 1).
//!
//! Full β-space Hessian + Cholesky solve per iteration. Without line
//! search this method diverges from β = 0 under weak regularization —
//! the paper's Figure-1 blow-up — because second derivatives vanish far
//! from the minimizer and the step overshoots. `line_search = true`
//! enables backtracking (the ablation the paper says one wants to avoid
//! paying for).

use super::objective::{require_native, FitConfig, FitResult, Optimizer, Stopper};
use crate::cox::derivatives::{beta_gradient_ws, beta_hessian_ws, Workspace};
use crate::cox::loss::loss_for_eta;
use crate::cox::{CoxProblem, CoxState};
use crate::error::{FastSurvivalError, Result};
use crate::linalg::{Cholesky, Matrix};
use crate::runtime::engine::CoxEngine;

/// Exact Newton. ℓ1 is not supported (the paper: "the exact Newton method
/// cannot be directly applied" to ℓ1 problems); `fit` returns a typed
/// [`FastSurvivalError::InvalidConfig`] if λ1 > 0.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExactNewton {
    pub line_search: bool,
}

impl Optimizer for ExactNewton {
    fn name(&self) -> &'static str {
        if self.line_search {
            "exact-newton+ls"
        } else {
            "exact-newton"
        }
    }

    fn fit_from(
        &self,
        problem: &CoxProblem,
        mut state: CoxState,
        config: &FitConfig,
        engine: &dyn CoxEngine,
    ) -> Result<FitResult> {
        require_native(self.name(), engine)?;
        let obj = config.objective;
        if obj.l1 != 0.0 {
            return Err(FastSurvivalError::InvalidConfig(
                "exact Newton does not handle ℓ1 (non-smooth) objectives".into(),
            ));
        }
        let p = problem.p();
        // One workspace across iterations: the gradient's prefix-weight
        // pass is shared with the Hessian's at each η (same version), and
        // buffers are reused between Newton steps.
        let mut ws = Workspace::default();
        let mut stopper = Stopper::new();
        let mut iters = 0;
        for it in 0..config.max_iters {
            let mut g = beta_gradient_ws(problem, &state, &mut ws);
            let mut h: Matrix = beta_hessian_ws(problem, &state, &mut ws);
            for l in 0..p {
                g[l] += 2.0 * obj.l2 * state.beta[l];
                h.set(l, l, h.get(l, l) + 2.0 * obj.l2);
            }
            // Numerical breakdown (η overflowed): record divergence, stop.
            if g.iter().any(|v| !v.is_finite()) || h.data.iter().any(|v| !v.is_finite()) {
                stopper.trace.diverged = true;
                break;
            }
            let (chol, _jitter) = Cholesky::factor_with_jitter(&h, 1e-10);
            let step = chol.solve(&g);

            let mut t = 1.0;
            if self.line_search {
                // Armijo backtracking on the penalized objective.
                let f0 = obj.value(problem, &state);
                let g_dot_d: f64 = g.iter().zip(&step).map(|(a, b)| -a * b).sum();
                loop {
                    let trial: Vec<f64> = state
                        .beta
                        .iter()
                        .zip(&step)
                        .map(|(b, s)| b - t * s)
                        .collect();
                    let eta = problem.x.matvec(&trial);
                    let f = loss_for_eta(problem, &eta)
                        + obj.l2 * trial.iter().map(|b| b * b).sum::<f64>();
                    if f <= f0 + 1e-4 * t * g_dot_d || t < 1e-10 {
                        break;
                    }
                    t *= 0.5;
                }
            }
            let new_beta: Vec<f64> =
                state.beta.iter().zip(&step).map(|(b, s)| b - t * s).collect();
            state.set_beta(problem, &new_beta);

            iters = it + 1;
            let loss = obj.value(problem, &state);
            if stopper.step(it, loss, config) {
                break;
            }
        }
        let objective_value = obj.value(problem, &state);
        Ok(FitResult { beta: state.beta, trace: stopper.trace, objective_value, iterations: iters })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::objective::Objective;
    use crate::optim::QuadraticSurrogate;
    use crate::data::SurvivalDataset;
    use crate::linalg::Matrix;
    use crate::util::rng::Rng;

    fn random_problem(n: usize, p: usize, seed: u64, beta_scale: f64) -> CoxProblem {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f64>> =
            (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        // Plant a real signal so the unpenalized optimum is away from 0.
        let time: Vec<f64> = (0..n)
            .map(|i| {
                let eta: f64 = (0..p).map(|j| cols[j][i]).sum::<f64>() * beta_scale;
                rng.exponential() / eta.exp()
            })
            .collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.8)).collect();
        CoxProblem::new(&SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "r"))
    }

    #[test]
    fn converges_with_strong_l2_near_optimum() {
        let pr = random_problem(80, 3, 1, 0.2);
        let cfg = FitConfig {
            objective: Objective { l1: 0.0, l2: 5.0 },
            max_iters: 50,
            tol: 1e-12,
            ..Default::default()
        };
        let rn = ExactNewton::default().fit(&pr, &cfg).unwrap();
        let rq = QuadraticSurrogate
            .fit(&pr, &FitConfig { max_iters: 2000, tol: 1e-13, ..cfg.clone() })
            .unwrap();
        assert!(!rn.trace.diverged);
        assert!(
            (rn.objective_value - rq.objective_value).abs() < 1e-5,
            "newton {} vs cd {}",
            rn.objective_value,
            rq.objective_value
        );
    }

    #[test]
    fn blows_up_on_binarized_data_with_weak_regularization() {
        // The paper's Figure-1 phenomenon: quantile-binarized features
        // include rare indicators with near-zero curvature at β = 0, so
        // the full Newton step overshoots and the loss explodes.
        use crate::data::binarize::{binarize, BinarizeConfig};
        use crate::data::datasets;
        let mut s = datasets::spec("flchain");
        s.n = 150;
        let raw = datasets::generate_stand_in(&s, 5);
        let ds = binarize(&raw, &BinarizeConfig { max_quantiles: 10, ..Default::default() });
        let pr = CoxProblem::new(&ds);
        let cfg = FitConfig {
            objective: Objective { l1: 0.0, l2: 0.01 },
            max_iters: 6,
            tol: 1e-14,
            ..Default::default()
        };
        let res = ExactNewton::default().fit(&pr, &cfg).unwrap();
        assert!(
            res.trace.ever_increased(1e-6) || res.trace.diverged,
            "expected plain Newton blow-up; losses {:?}",
            res.trace.points.iter().map(|p| p.loss).collect::<Vec<_>>()
        );
        // Our surrogate on the same problem stays monotone (the contrast
        // the paper draws in Figure 1).
        let rc = crate::optim::CubicSurrogate
            .fit(&pr, &FitConfig { max_iters: 10, ..cfg.clone() })
            .unwrap();
        assert!(rc.trace.monotone(1e-9));
    }

    #[test]
    fn line_search_newton_is_monotone() {
        let pr = random_problem(100, 5, 2, 1.5);
        let cfg = FitConfig {
            objective: Objective { l1: 0.0, l2: 0.01 },
            max_iters: 20,
            tol: 1e-14,
            ..Default::default()
        };
        let ls = ExactNewton { line_search: true }.fit(&pr, &cfg).unwrap();
        assert!(ls.trace.monotone(1e-8), "line-search Newton must be monotone");
    }

    #[test]
    fn rejects_l1_with_typed_error() {
        let pr = random_problem(20, 2, 3, 0.2);
        let cfg = FitConfig {
            objective: Objective { l1: 1.0, l2: 0.0 },
            ..Default::default()
        };
        let err = ExactNewton::default().fit(&pr, &cfg).unwrap_err();
        assert!(
            matches!(err, FastSurvivalError::InvalidConfig(_)),
            "expected InvalidConfig, got {err}"
        );
        assert!(err.to_string().contains("exact Newton"));
    }
}
