//! Coordinate descent on the quadratic surrogate (Eq. 15 / 17 / 20).
//!
//! Per coordinate: one O(n) pass for d1, then the analytic step
//! Δ = −a/b (or the ℓ1 closed form), where b is the *explicit* Lipschitz
//! constant L2_l from Theorem 3.4 — no line search, monotone descent,
//! global convergence.

use super::cd::{fit_support_with, SurrogateKind};
use super::objective::{engine_cd_fit, FitConfig, FitResult, Objective, Optimizer};
use super::prox::{quad_l1_step, quad_step};
use crate::cox::derivatives::{coord_d1_ws_b, Workspace};
use crate::cox::lipschitz::{all_lipschitz, LipschitzPair};
use crate::cox::{CoxProblem, CoxState};
use crate::error::Result;
use crate::runtime::engine::CoxEngine;
use crate::util::compute::{default_backend, KernelBackend};

/// The paper's first-order surrogate method.
#[derive(Clone, Copy, Debug, Default)]
pub struct QuadraticSurrogate;

/// One quadratic-surrogate coordinate step; returns the applied Δ.
/// ℓ2 is absorbed into the surrogate coefficients (footnote 2): the
/// penalized first derivative is d1 + 2λ2·β_l and the penalized Lipschitz
/// constant is L2 + 2λ2 (the ridge gradient is exactly linear).
#[inline]
pub fn quad_coord_step(
    problem: &CoxProblem,
    state: &mut CoxState,
    l: usize,
    lip: LipschitzPair,
    obj: Objective,
) -> f64 {
    quad_coord_step_ws(problem, state, &mut Workspace::default(), l, lip, obj)
}

/// [`quad_coord_step`] through a shared [`Workspace`]: steps that leave
/// η untouched (the common case deep into an ℓ1 fit) reuse the cached
/// risk-set weights instead of re-accumulating the S0 prefix.
#[inline]
pub fn quad_coord_step_ws(
    problem: &CoxProblem,
    state: &mut CoxState,
    ws: &mut Workspace,
    l: usize,
    lip: LipschitzPair,
    obj: Objective,
) -> f64 {
    quad_coord_step_ws_b(problem, state, ws, l, lip, obj, default_backend())
}

/// [`quad_coord_step_ws`] with an explicit kernel backend threaded into
/// both the derivative pass and the incremental η/w update.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn quad_coord_step_ws_b(
    problem: &CoxProblem,
    state: &mut CoxState,
    ws: &mut Workspace,
    l: usize,
    lip: LipschitzPair,
    obj: Objective,
    backend: KernelBackend,
) -> f64 {
    let b = lip.l2 + 2.0 * obj.l2;
    if b <= 0.0 {
        return 0.0;
    }
    let d1 = coord_d1_ws_b(problem, state, ws, l, backend);
    let a = d1 + 2.0 * obj.l2 * state.beta[l];
    let delta = if obj.l1 > 0.0 {
        quad_l1_step(a, b, state.beta[l], obj.l1)
    } else {
        quad_step(a, b)
    };
    state.update_coord_col_b(backend, problem.x.col(l), problem.col_binary[l], l, delta);
    delta
}

/// Run quadratic-surrogate CD sweeps over `coords` until `config` stops.
/// Thin wrapper over the shared support-restricted routine in
/// [`super::cd`] — the sweep loop lives there once for both surrogates.
pub fn fit_support(
    problem: &CoxProblem,
    state: CoxState,
    coords: &[usize],
    config: &FitConfig,
    lip: &[LipschitzPair],
) -> FitResult {
    fit_support_with(problem, state, coords, config, lip, SurrogateKind::Quadratic)
}

impl Optimizer for QuadraticSurrogate {
    fn name(&self) -> &'static str {
        "quadratic-surrogate"
    }

    fn fit_from(
        &self,
        problem: &CoxProblem,
        state: CoxState,
        config: &FitConfig,
        engine: &dyn CoxEngine,
    ) -> Result<FitResult> {
        if engine.is_native() {
            // Fused in-process kernels — the paper's hot path.
            let lip = all_lipschitz(problem);
            let coords: Vec<usize> = (0..problem.p()).collect();
            return Ok(fit_support(problem, state, &coords, config, &lip));
        }
        // Engine-served quantities: same sweep, every Cox term remote.
        let obj = config.objective;
        engine_cd_fit(problem, state, config, engine, |engine, problem, state, l, lip| {
            let b = lip.l2 + 2.0 * obj.l2;
            if b <= 0.0 {
                return Ok(());
            }
            let d1 = engine.coord_d1(problem, state, l)?;
            let a = d1 + 2.0 * obj.l2 * state.beta[l];
            let delta = if obj.l1 > 0.0 {
                quad_l1_step(a, b, state.beta[l], obj.l1)
            } else {
                quad_step(a, b)
            };
            state.update_coord(problem, l, delta);
            Ok(())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cox::derivatives::beta_gradient;
    use crate::data::SurvivalDataset;
    use crate::linalg::Matrix;
    use crate::util::rng::Rng;

    pub(crate) fn random_problem(n: usize, p: usize, seed: u64) -> CoxProblem {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f64>> =
            (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let time: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.5, 9.5)).collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.7)).collect();
        CoxProblem::new(&SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "r"))
    }

    #[test]
    fn monotone_decrease_unregularized() {
        let pr = random_problem(60, 5, 1);
        let cfg = FitConfig { max_iters: 50, ..Default::default() };
        let res = QuadraticSurrogate.fit(&pr, &cfg).unwrap();
        assert!(res.trace.monotone(1e-10), "loss must never increase");
        assert!(res.trace.points.len() > 2);
    }

    #[test]
    fn reaches_stationarity_with_l2() {
        let pr = random_problem(80, 4, 2);
        let cfg = FitConfig {
            objective: Objective { l1: 0.0, l2: 1.0 },
            max_iters: 2000,
            tol: 1e-13,
            ..Default::default()
        };
        let res = QuadraticSurrogate.fit(&pr, &cfg).unwrap();
        // Stationarity: penalized gradient ≈ 0.
        let st = CoxState::from_beta(&pr, &res.beta);
        let g = beta_gradient(&pr, &st);
        for l in 0..pr.p() {
            let pg = g[l] + 2.0 * res.beta[l];
            assert!(pg.abs() < 1e-3, "coord {l}: penalized grad {pg}");
        }
    }

    #[test]
    fn l1_produces_sparsity() {
        let pr = random_problem(100, 8, 3);
        let strong = FitConfig {
            objective: Objective { l1: 20.0, l2: 0.0 },
            max_iters: 200,
            ..Default::default()
        };
        let weak = FitConfig {
            objective: Objective { l1: 0.01, l2: 0.0 },
            max_iters: 200,
            ..Default::default()
        };
        let rs = QuadraticSurrogate.fit(&pr, &strong).unwrap();
        let rw = QuadraticSurrogate.fit(&pr, &weak).unwrap();
        let nnz_s = rs.beta.iter().filter(|b| b.abs() > 1e-10).count();
        let nnz_w = rw.beta.iter().filter(|b| b.abs() > 1e-10).count();
        assert!(nnz_s < nnz_w, "strong λ1 must be sparser: {nnz_s} vs {nnz_w}");
    }

    #[test]
    fn support_restricted_fit_touches_only_support() {
        let pr = random_problem(50, 6, 4);
        let lip = all_lipschitz(&pr);
        let cfg = FitConfig { max_iters: 30, ..Default::default() };
        let res = fit_support(&pr, CoxState::zeros(&pr), &[1, 4], &cfg, &lip);
        for (l, b) in res.beta.iter().enumerate() {
            if l != 1 && l != 4 {
                assert_eq!(*b, 0.0);
            }
        }
        assert!(res.beta[1].abs() + res.beta[4].abs() > 0.0);
    }

    #[test]
    fn l1_kkt_conditions_hold() {
        let pr = random_problem(70, 5, 5);
        let l1 = 2.0;
        let cfg = FitConfig {
            objective: Objective { l1, l2: 0.5 },
            max_iters: 2000,
            tol: 1e-13,
            ..Default::default()
        };
        let res = QuadraticSurrogate.fit(&pr, &cfg).unwrap();
        let st = CoxState::from_beta(&pr, &res.beta);
        let g = beta_gradient(&pr, &st);
        for l in 0..pr.p() {
            let pg = g[l] + 2.0 * 0.5 * res.beta[l];
            if res.beta[l].abs() > 1e-8 {
                assert!(
                    (pg + l1 * res.beta[l].signum()).abs() < 1e-3,
                    "active KKT at {l}: {pg}"
                );
            } else {
                assert!(pg.abs() <= l1 + 1e-3, "inactive KKT at {l}: |{pg}| > {l1}");
            }
        }
    }
}
