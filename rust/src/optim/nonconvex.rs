//! Nonconvex separable penalties — SCAD \[15\] and MCP \[68\].
//!
//! Section 3.5 lists both as qualifying penalties for the surrogate
//! framework, and the conclusion poses their analytical solutions as an
//! open extension. For the *quadratic* surrogate the penalized
//! subproblem `min_Δ aΔ + ½bΔ² + pen(|c+Δ|)` has a known closed form
//! for both penalties whenever the surrogate curvature `b` exceeds the
//! penalty's concavity (b > 1/γ for MCP, b > 1/(γ−1) for SCAD), which
//! Theorem 3.4's explicit constants let us check up front.

use super::objective::{require_native, FitConfig, FitResult, Optimizer, Stopper};
use crate::cox::derivatives::coord_d1;
use crate::cox::lipschitz::all_lipschitz;
use crate::cox::{CoxProblem, CoxState};
use crate::error::Result;
use crate::runtime::engine::CoxEngine;
use crate::linalg::vecops::soft_threshold;

/// Penalty family for [`NonconvexSurrogate`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Penalty {
    /// Smoothly Clipped Absolute Deviation (Fan & Li), parameter γ > 2.
    Scad { lambda: f64, gamma: f64 },
    /// Minimax Concave Penalty (Zhang), parameter γ > 1.
    Mcp { lambda: f64, gamma: f64 },
}

impl Penalty {
    /// Penalty value at |t|.
    pub fn value(&self, t: f64) -> f64 {
        let t = t.abs();
        match *self {
            Penalty::Scad { lambda, gamma } => {
                if t <= lambda {
                    lambda * t
                } else if t <= gamma * lambda {
                    (2.0 * gamma * lambda * t - t * t - lambda * lambda)
                        / (2.0 * (gamma - 1.0))
                } else {
                    lambda * lambda * (gamma + 1.0) / 2.0
                }
            }
            Penalty::Mcp { lambda, gamma } => {
                if t <= gamma * lambda {
                    lambda * t - t * t / (2.0 * gamma)
                } else {
                    0.5 * gamma * lambda * lambda
                }
            }
        }
    }

    /// Solve `min_z ½ b (z − u)² + pen(|z|)` — the scaled proximal
    /// operator the quadratic surrogate step reduces to (u = c − a/b).
    /// Requires b to dominate the concavity (checked by the caller).
    pub fn prox(&self, u: f64, b: f64) -> f64 {
        match *self {
            Penalty::Scad { lambda, gamma } => {
                // Fan & Li's three-zone solution, generalized to
                // curvature b (glmnet-style): thresholds scale by 1/b.
                let au = u.abs();
                let z = if au <= lambda * (1.0 + 1.0 / b) {
                    soft_threshold(u, lambda / b)
                } else if au <= gamma * lambda {
                    // Middle zone: ½b(z−u)² + (scad middle)(z); stationarity
                    // b(z−u) + (γλ−z)/(γ−1) = 0 (for z>0)
                    let denom = b - 1.0 / (gamma - 1.0);
                    debug_assert!(denom > 0.0, "surrogate curvature must beat SCAD concavity");
                    let num = b * au - gamma * lambda / (gamma - 1.0);
                    u.signum() * (num / denom).max(0.0)
                } else {
                    u
                };
                // Guard nonconvexity: pick the better of z and the
                // candidates at the zone boundaries.
                self.pick_best(u, b, &[z, soft_threshold(u, lambda / b), u])
            }
            Penalty::Mcp { lambda, gamma } => {
                let au = u.abs();
                let z = if au <= gamma * lambda {
                    let denom = b - 1.0 / gamma;
                    debug_assert!(denom > 0.0, "surrogate curvature must beat MCP concavity");
                    u.signum() * (soft_threshold(au, lambda / b).abs() * b / denom).min(au)
                } else {
                    u
                };
                self.pick_best(u, b, &[z, 0.0, u])
            }
        }
    }

    fn pick_best(&self, u: f64, b: f64, candidates: &[f64]) -> f64 {
        let obj = |z: f64| 0.5 * b * (z - u) * (z - u) + self.value(z);
        let mut best = candidates[0];
        let mut best_v = obj(best);
        for &c in &candidates[1..] {
            let v = obj(c);
            if v < best_v {
                best_v = v;
                best = c;
            }
        }
        best
    }
}

/// Quadratic-surrogate CD with a SCAD/MCP penalty.
#[derive(Clone, Copy, Debug)]
pub struct NonconvexSurrogate {
    pub penalty: Penalty,
}

impl Optimizer for NonconvexSurrogate {
    fn name(&self) -> &'static str {
        match self.penalty {
            Penalty::Scad { .. } => "scad-surrogate",
            Penalty::Mcp { .. } => "mcp-surrogate",
        }
    }

    fn fit_from(
        &self,
        problem: &CoxProblem,
        mut state: CoxState,
        config: &FitConfig,
        engine: &dyn CoxEngine,
    ) -> Result<FitResult> {
        require_native(self.name(), engine)?;
        let lip = all_lipschitz(problem);
        let mut stopper = Stopper::new();
        let mut iters = 0;
        let pen_total = |beta: &[f64]| -> f64 {
            beta.iter().map(|&b| self.penalty.value(b)).sum()
        };
        for it in 0..config.max_iters {
            for l in 0..problem.p() {
                // Curvature must beat the penalty's concavity for the
                // closed form to be a global prox; lift b if needed
                // (still a valid majorizer — just a smaller step).
                let concavity = match self.penalty {
                    Penalty::Scad { gamma, .. } => 1.0 / (gamma - 1.0),
                    Penalty::Mcp { gamma, .. } => 1.0 / gamma,
                };
                let b = (lip[l].l2 + 2.0 * config.objective.l2).max(concavity * 1.5);
                if lip[l].l2 <= 0.0 {
                    continue;
                }
                let a = coord_d1(problem, &state, l)
                    + 2.0 * config.objective.l2 * state.beta[l];
                let u = state.beta[l] - a / b;
                let new_b = self.penalty.prox(u, b);
                let delta = new_b - state.beta[l];
                if delta != 0.0 {
                    state.update_coord(problem, l, delta);
                }
            }
            iters = it + 1;
            let loss = crate::cox::loss::loss(problem, &state)
                + config.objective.l2 * state.beta.iter().map(|b| b * b).sum::<f64>()
                + pen_total(&state.beta);
            if stopper.step(it, loss, config) {
                break;
            }
        }
        let objective_value = crate::cox::loss::loss(problem, &state)
            + config.objective.l2 * state.beta.iter().map(|b| b * b).sum::<f64>()
            + pen_total(&state.beta);
        Ok(FitResult { beta: state.beta, trace: stopper.trace, objective_value, iterations: iters })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::util::proptest::check;

    #[test]
    fn penalty_values_known_points() {
        let scad = Penalty::Scad { lambda: 1.0, gamma: 3.7 };
        assert_eq!(scad.value(0.0), 0.0);
        assert!((scad.value(1.0) - 1.0).abs() < 1e-12); // λt zone
        assert!((scad.value(10.0) - (3.7 + 1.0) / 2.0).abs() < 1e-12); // flat zone
        let mcp = Penalty::Mcp { lambda: 1.0, gamma: 2.0 };
        assert!((mcp.value(0.5) - (0.5 - 0.0625)).abs() < 1e-12);
        assert!((mcp.value(5.0) - 1.0).abs() < 1e-12); // flat: γλ²/2
    }

    #[test]
    fn prox_minimizes_subproblem() {
        // Golden-section can't handle nonconvexity in general, so check
        // optimality by dense grid instead.
        for pen in [
            Penalty::Scad { lambda: 0.8, gamma: 3.7 },
            Penalty::Mcp { lambda: 0.8, gamma: 2.5 },
        ] {
            check(
                "nonconvex-prox",
                31,
                80,
                |r| (r.uniform_range(-4.0, 4.0), r.uniform_range(1.0, 6.0)),
                |&(u, b)| {
                    let z = pen.prox(u, b);
                    let obj = |t: f64| 0.5 * b * (t - u) * (t - u) + pen.value(t);
                    let vz = obj(z);
                    let mut t = -5.0;
                    while t <= 5.0 {
                        if obj(t) < vz - 1e-6 {
                            return Err(format!(
                                "prox({u}, {b}) = {z} (v={vz}) beaten by t={t} (v={})",
                                obj(t)
                            ));
                        }
                        t += 0.001;
                    }
                    Ok(())
                },
            );
        }
    }

    #[test]
    fn large_signals_are_unbiased() {
        // The hallmark of SCAD/MCP: big |u| passes through unshrunk.
        let scad = Penalty::Scad { lambda: 0.5, gamma: 3.7 };
        let mcp = Penalty::Mcp { lambda: 0.5, gamma: 2.5 };
        assert_eq!(scad.prox(10.0, 2.0), 10.0);
        assert_eq!(mcp.prox(10.0, 2.0), 10.0);
        // ... while lasso would shrink by λ/b.
        assert!(soft_threshold(10.0, 0.25) < 10.0);
    }

    #[test]
    fn fit_is_sparse_and_less_biased_than_lasso() {
        use crate::optim::{FitConfig, Objective, QuadraticSurrogate};
        let ds = generate(&SyntheticConfig { n: 400, p: 20, rho: 0.3, k: 3, s: 0.1, seed: 9 });
        let pr = CoxProblem::new(&ds);
        let lam = 3.0;
        let cfg = FitConfig {
            objective: Objective { l1: 0.0, l2: 0.0 },
            max_iters: 200,
            tol: 1e-10,
            ..Default::default()
        };
        let mcp = NonconvexSurrogate { penalty: Penalty::Mcp { lambda: lam, gamma: 3.0 } }
            .fit(&pr, &cfg)
            .unwrap();
        let lasso_cfg = FitConfig {
            objective: Objective { l1: lam, l2: 0.0 },
            ..cfg.clone()
        };
        let lasso = QuadraticSurrogate.fit(&pr, &lasso_cfg).unwrap();
        let nnz = |b: &[f64]| b.iter().filter(|v| v.abs() > 1e-8).count();
        assert!(nnz(&mcp.beta) <= pr.p());
        assert!(nnz(&mcp.beta) >= 3, "MCP should keep the true signals");
        // On the true support, MCP coefficients should be larger in
        // magnitude (less biased) than lasso's.
        let truth = ds.true_beta.as_ref().unwrap();
        let mut mcp_mag = 0.0;
        let mut lasso_mag = 0.0;
        for (j, t) in truth.iter().enumerate() {
            if *t != 0.0 {
                mcp_mag += mcp.beta[j].abs();
                lasso_mag += lasso.beta[j].abs();
            }
        }
        assert!(
            mcp_mag > lasso_mag,
            "MCP {mcp_mag} should dominate lasso {lasso_mag} on the support"
        );
    }

    #[test]
    fn monotone_descent_holds() {
        let ds = generate(&SyntheticConfig { n: 200, p: 10, rho: 0.5, k: 2, s: 0.1, seed: 10 });
        let pr = CoxProblem::new(&ds);
        let cfg = FitConfig { max_iters: 60, ..Default::default() };
        for pen in [
            Penalty::Scad { lambda: 1.0, gamma: 3.7 },
            Penalty::Mcp { lambda: 1.0, gamma: 2.5 },
        ] {
            let res = NonconvexSurrogate { penalty: pen }.fit(&pr, &cfg).unwrap();
            assert!(res.trace.monotone(1e-8), "{pen:?} must descend monotonically");
        }
    }
}
