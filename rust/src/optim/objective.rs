//! Shared optimizer interface: objective spec, fit config, trace, result.
//!
//! Since the unified-API redesign, [`Optimizer::fit_from`] threads a
//! [`CoxEngine`] through every method: the same optimizer loop runs on
//! the native Rust kernels or on the AOT-compiled XLA artifacts, and
//! engine selection is a caller-side choice rather than a separate fit
//! path. Optimizers are fallible ([`crate::error::Result`]) because
//! engines are.

use crate::cox::lipschitz::LipschitzPair;
use crate::cox::loss::penalized_loss;
use crate::cox::{CoxProblem, CoxState};
use crate::error::{FastSurvivalError, Result};
use crate::runtime::engine::{CoxEngine, NativeEngine};
use crate::util::compute::ResolvedCompute;
use std::time::Instant;

/// The regularized objective ℓ(β) + λ1‖β‖₁ + λ2‖β‖₂².
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Objective {
    pub l1: f64,
    pub l2: f64,
}

impl Objective {
    pub fn value(&self, problem: &CoxProblem, state: &CoxState) -> f64 {
        penalized_loss(problem, state, self.l1, self.l2)
    }

    /// The penalty term λ1‖β‖₁ + λ2‖β‖₂² alone — added to an
    /// engine-served unpenalized loss.
    pub fn penalty(&self, beta: &[f64]) -> f64 {
        self.l1 * beta.iter().map(|b| b.abs()).sum::<f64>()
            + self.l2 * beta.iter().map(|b| b * b).sum::<f64>()
    }
}

/// Stopping / recording configuration — one config for every optimizer
/// and every engine (the old engine-specific fit config folded into
/// this).
#[derive(Clone, Debug)]
pub struct FitConfig {
    pub objective: Objective,
    /// Maximum outer iterations (CD sweeps, Newton steps, ...).
    pub max_iters: usize,
    /// Relative loss-decrease tolerance.
    pub tol: f64,
    /// Wall-clock budget in seconds (0 = unlimited). Exhaustion is
    /// recorded on [`Trace::budget_exhausted`].
    pub budget_secs: f64,
    /// Record a loss-history trace (small overhead: one loss eval/iter).
    pub record_trace: bool,
    /// Kernel backend / thread budget / blocking, resolved once before
    /// the fit (see [`crate::util::compute::Compute`]); the environment
    /// is never re-read inside optimizer loops.
    pub compute: ResolvedCompute,
}

impl Default for FitConfig {
    fn default() -> Self {
        FitConfig {
            objective: Objective::default(),
            max_iters: 200,
            tol: 1e-9,
            budget_secs: 0.0,
            record_trace: true,
            compute: ResolvedCompute::ambient(),
        }
    }
}

/// One trace point: (iteration index, seconds since fit start, loss),
/// plus per-point solver effort — sweeps completed and the max KKT
/// residual when the engine computes one (exact streamed/sharded CD
/// does; plain loss-tolerance engines record `None`).
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    pub iter: usize,
    pub secs: f64,
    pub loss: f64,
    /// Coordinate sweeps completed when this point was recorded (for
    /// one-sweep-per-iteration engines this is `iter + 1`).
    pub sweeps: usize,
    /// Max KKT residual over coordinates at this point, if computed.
    pub kkt: Option<f64>,
}

/// Loss history with divergence bookkeeping.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub points: Vec<TracePoint>,
    pub diverged: bool,
    pub converged: bool,
    /// True when the fit stopped because `budget_secs` ran out, so
    /// callers can distinguish a timeout from convergence.
    pub budget_exhausted: bool,
}

impl Trace {
    pub fn push(&mut self, iter: usize, start: Instant, loss: f64) {
        self.push_full(iter, start, loss, iter + 1, None);
    }

    /// [`Trace::push`] with explicit solver effort: cumulative sweep
    /// count and the iteration's max KKT residual (if computed).
    pub fn push_full(
        &mut self,
        iter: usize,
        start: Instant,
        loss: f64,
        sweeps: usize,
        kkt: Option<f64>,
    ) {
        self.points.push(TracePoint {
            iter,
            secs: start.elapsed().as_secs_f64(),
            loss,
            sweeps,
            kkt,
        });
    }

    /// True if the loss ever increased from one record to the next by more
    /// than `tol` (the Newton blow-up signature in Figure 1).
    pub fn ever_increased(&self, tol: f64) -> bool {
        self.points.windows(2).any(|w| w[1].loss > w[0].loss + tol)
    }

    /// Monotone non-increasing (the paper's guarantee for surrogates).
    pub fn monotone(&self, tol: f64) -> bool {
        !self.ever_increased(tol)
    }

    pub fn final_loss(&self) -> f64 {
        self.points.last().map(|p| p.loss).unwrap_or(f64::NAN)
    }
}

/// Fit output.
#[derive(Clone, Debug)]
pub struct FitResult {
    pub beta: Vec<f64>,
    pub trace: Trace,
    /// Final penalized objective value.
    pub objective_value: f64,
    pub iterations: usize,
}

/// The optimizer interface shared by our methods and every baseline.
///
/// All Cox quantities flow through the [`CoxEngine`] passed to
/// [`Optimizer::fit_from`]; [`Optimizer::fit`] is the β = 0,
/// native-engine convenience used everywhere the paper initializes
/// from zero.
pub trait Optimizer {
    /// Human-readable name (figure legends).
    fn name(&self) -> &'static str;

    /// Fit from β = 0 (the paper's initialization everywhere) on the
    /// in-process native engine.
    fn fit(&self, problem: &CoxProblem, config: &FitConfig) -> Result<FitResult> {
        let state = CoxState::zeros(problem);
        self.fit_from(problem, state, config, &NativeEngine)
    }

    /// Fit from a warm-started state, with every Cox quantity (loss,
    /// derivatives, Lipschitz constants) served by `engine`.
    fn fit_from(
        &self,
        problem: &CoxProblem,
        state: CoxState,
        config: &FitConfig,
        engine: &dyn CoxEngine,
    ) -> Result<FitResult>;
}

/// Guard for baselines that need full-gradient/Hessian kernels not served
/// through the engine abstraction: they run natively or not at all.
pub(crate) fn require_native(optimizer: &str, engine: &dyn CoxEngine) -> Result<()> {
    if engine.is_native() {
        Ok(())
    } else {
        Err(FastSurvivalError::Unsupported(format!(
            "optimizer {optimizer:?} needs full-gradient/Hessian kernels that only the \
             native engine provides (got engine {:?}); use the quadratic or cubic \
             surrogate for non-native engines",
            engine.name()
        )))
    }
}

/// The engine-generic coordinate-descent outer loop shared by the
/// quadratic and cubic surrogates: prefetch per-coordinate Lipschitz
/// constants, sweep `step` over all coordinates, evaluate the penalized
/// loss through the engine once per sweep, and stop via [`Stopper`].
/// Exists once so the two surrogates cannot drift apart on stopping or
/// penalty semantics.
pub(crate) fn engine_cd_fit<F>(
    problem: &CoxProblem,
    mut state: CoxState,
    config: &FitConfig,
    engine: &dyn CoxEngine,
    mut step: F,
) -> Result<FitResult>
where
    F: FnMut(&dyn CoxEngine, &CoxProblem, &mut CoxState, usize, LipschitzPair) -> Result<()>,
{
    let obj = config.objective;
    let p = problem.p();
    let lip: Vec<LipschitzPair> =
        (0..p).map(|l| engine.lipschitz(problem, l)).collect::<Result<_>>()?;
    let mut stopper = Stopper::new();
    let mut iters = 0;
    // The last in-loop loss is exact for the final state, so the final
    // objective needs no extra engine round-trip (each one is a full
    // PJRT launch on the XLA engine).
    let mut last_loss = None;
    for it in 0..config.max_iters {
        for l in 0..p {
            step(engine, problem, &mut state, l, lip[l])?;
        }
        iters = it + 1;
        let loss = engine.loss(problem, &state)? + obj.penalty(&state.beta);
        last_loss = Some(loss);
        if stopper.step(it, loss, config) {
            break;
        }
    }
    let objective_value = match last_loss {
        Some(loss) => loss,
        None => engine.loss(problem, &state)? + obj.penalty(&state.beta),
    };
    Ok(FitResult { beta: state.beta, trace: stopper.trace, objective_value, iterations: iters })
}

/// Shared stopping logic for iterative fits.
pub(crate) struct Stopper {
    start: Instant,
    prev_loss: f64,
    pub trace: Trace,
}

impl Stopper {
    pub fn new() -> Self {
        Stopper { start: Instant::now(), prev_loss: f64::INFINITY, trace: Trace::default() }
    }

    /// Record the end-of-iteration loss; returns true if fitting should
    /// stop (converged, diverged, or out of budget).
    pub fn step(&mut self, iter: usize, loss: f64, config: &FitConfig) -> bool {
        self.step_with(iter, loss, None, config)
    }

    /// [`Stopper::step`] for engines that also compute a per-iteration
    /// max KKT residual, so the trace records optimality progress
    /// alongside loss decrease.
    pub fn step_with(
        &mut self,
        iter: usize,
        loss: f64,
        kkt: Option<f64>,
        config: &FitConfig,
    ) -> bool {
        if config.record_trace {
            self.trace.push_full(iter, self.start, loss, iter + 1, kkt);
        }
        if !loss.is_finite() || loss > 1e300 {
            self.trace.diverged = true;
            return true;
        }
        let rel = (self.prev_loss - loss).abs() / (self.prev_loss.abs() + 1.0);
        let converged = self.prev_loss.is_finite() && rel < config.tol;
        self.prev_loss = loss;
        if converged {
            self.trace.converged = true;
            return true;
        }
        if config.budget_secs > 0.0 && self.start.elapsed().as_secs_f64() > config.budget_secs {
            self.trace.budget_exhausted = true;
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_monotone_detection() {
        let mut t = Trace::default();
        let start = Instant::now();
        for (i, l) in [5.0, 4.0, 3.5].iter().enumerate() {
            t.push(i, start, *l);
        }
        assert!(t.monotone(1e-12));
        t.push(3, start, 4.2);
        assert!(t.ever_increased(1e-12));
        assert_eq!(t.final_loss(), 4.2);
    }

    #[test]
    fn trace_points_carry_sweeps_and_kkt() {
        let mut t = Trace::default();
        let start = Instant::now();
        t.push(0, start, 5.0);
        t.push_full(1, start, 4.0, 7, Some(1e-3));
        assert_eq!(t.points[0].sweeps, 1);
        assert!(t.points[0].kkt.is_none());
        assert_eq!(t.points[1].sweeps, 7);
        assert_eq!(t.points[1].kkt, Some(1e-3));

        let mut s = Stopper::new();
        let cfg = FitConfig::default();
        assert!(!s.step_with(0, 10.0, Some(0.5), &cfg));
        assert_eq!(s.trace.points[0].kkt, Some(0.5));
    }

    #[test]
    fn stopper_converges_on_flat_loss() {
        let mut s = Stopper::new();
        let cfg = FitConfig { tol: 1e-6, ..Default::default() };
        assert!(!s.step(0, 10.0, &cfg));
        assert!(!s.step(1, 9.0, &cfg));
        assert!(s.step(2, 9.0 - 1e-9, &cfg));
        assert!(s.trace.converged);
        assert!(!s.trace.budget_exhausted);
    }

    #[test]
    fn stopper_flags_divergence() {
        let mut s = Stopper::new();
        let cfg = FitConfig::default();
        assert!(!s.step(0, 10.0, &cfg));
        assert!(s.step(1, f64::INFINITY, &cfg));
        assert!(s.trace.diverged);
    }

    #[test]
    fn stopper_marks_budget_exhaustion() {
        let mut s = Stopper::new();
        // A still-improving loss sequence that runs out of wall clock:
        // the stop must be attributed to the budget, not convergence.
        let cfg = FitConfig { tol: 1e-12, budget_secs: 1e-9, ..Default::default() };
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(s.step(0, 10.0, &cfg), "expired budget must stop the fit");
        assert!(s.trace.budget_exhausted);
        assert!(!s.trace.converged);
        assert!(!s.trace.diverged);
    }

    #[test]
    fn objective_penalty_matches_value_decomposition() {
        let obj = Objective { l1: 2.0, l2: 0.5 };
        let beta = [1.0, -3.0, 0.0];
        let expect = 2.0 * 4.0 + 0.5 * 10.0;
        assert!((obj.penalty(&beta) - expect).abs() < 1e-12);
    }
}
