//! Shared optimizer interface: objective spec, fit config, trace, result.

use crate::cox::loss::penalized_loss;
use crate::cox::{CoxProblem, CoxState};
use std::time::Instant;

/// The regularized objective ℓ(β) + λ1‖β‖₁ + λ2‖β‖₂².
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Objective {
    pub l1: f64,
    pub l2: f64,
}

impl Objective {
    pub fn value(&self, problem: &CoxProblem, state: &CoxState) -> f64 {
        penalized_loss(problem, state, self.l1, self.l2)
    }
}

/// Stopping / recording configuration.
#[derive(Clone, Debug)]
pub struct FitConfig {
    pub objective: Objective,
    /// Maximum outer iterations (CD sweeps, Newton steps, ...).
    pub max_iters: usize,
    /// Relative loss-decrease tolerance.
    pub tol: f64,
    /// Wall-clock budget in seconds (0 = unlimited).
    pub budget_secs: f64,
    /// Record a loss-history trace (small overhead: one loss eval/iter).
    pub record_trace: bool,
}

impl Default for FitConfig {
    fn default() -> Self {
        FitConfig {
            objective: Objective::default(),
            max_iters: 200,
            tol: 1e-9,
            budget_secs: 0.0,
            record_trace: true,
        }
    }
}

/// One trace point: (iteration index, seconds since fit start, loss).
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    pub iter: usize,
    pub secs: f64,
    pub loss: f64,
}

/// Loss history with divergence bookkeeping.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub points: Vec<TracePoint>,
    pub diverged: bool,
    pub converged: bool,
}

impl Trace {
    pub fn push(&mut self, iter: usize, start: Instant, loss: f64) {
        self.points.push(TracePoint { iter, secs: start.elapsed().as_secs_f64(), loss });
    }

    /// True if the loss ever increased from one record to the next by more
    /// than `tol` (the Newton blow-up signature in Figure 1).
    pub fn ever_increased(&self, tol: f64) -> bool {
        self.points.windows(2).any(|w| w[1].loss > w[0].loss + tol)
    }

    /// Monotone non-increasing (the paper's guarantee for surrogates).
    pub fn monotone(&self, tol: f64) -> bool {
        !self.ever_increased(tol)
    }

    pub fn final_loss(&self) -> f64 {
        self.points.last().map(|p| p.loss).unwrap_or(f64::NAN)
    }
}

/// Fit output.
#[derive(Clone, Debug)]
pub struct FitResult {
    pub beta: Vec<f64>,
    pub trace: Trace,
    /// Final penalized objective value.
    pub objective_value: f64,
    pub iterations: usize,
}

/// The optimizer interface shared by our methods and every baseline.
pub trait Optimizer {
    /// Human-readable name (figure legends).
    fn name(&self) -> &'static str;

    /// Fit from β = 0 (the paper's initialization everywhere).
    fn fit(&self, problem: &CoxProblem, config: &FitConfig) -> FitResult {
        let state = CoxState::zeros(problem);
        self.fit_from(problem, state, config)
    }

    /// Fit from a warm-started state.
    fn fit_from(&self, problem: &CoxProblem, state: CoxState, config: &FitConfig) -> FitResult;
}

/// Shared stopping logic for iterative fits.
pub(crate) struct Stopper {
    start: Instant,
    prev_loss: f64,
    pub trace: Trace,
}

impl Stopper {
    pub fn new() -> Self {
        Stopper { start: Instant::now(), prev_loss: f64::INFINITY, trace: Trace::default() }
    }

    /// Record the end-of-iteration loss; returns true if fitting should
    /// stop (converged, diverged, or out of budget).
    pub fn step(&mut self, iter: usize, loss: f64, config: &FitConfig) -> bool {
        if config.record_trace {
            self.trace.push(iter, self.start, loss);
        }
        if !loss.is_finite() || loss > 1e300 {
            self.trace.diverged = true;
            return true;
        }
        let rel = (self.prev_loss - loss).abs() / (self.prev_loss.abs() + 1.0);
        let converged = self.prev_loss.is_finite() && rel < config.tol;
        self.prev_loss = loss;
        if converged {
            self.trace.converged = true;
            return true;
        }
        if config.budget_secs > 0.0 && self.start.elapsed().as_secs_f64() > config.budget_secs {
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_monotone_detection() {
        let mut t = Trace::default();
        let start = Instant::now();
        for (i, l) in [5.0, 4.0, 3.5].iter().enumerate() {
            t.push(i, start, *l);
        }
        assert!(t.monotone(1e-12));
        t.push(3, start, 4.2);
        assert!(t.ever_increased(1e-12));
        assert_eq!(t.final_loss(), 4.2);
    }

    #[test]
    fn stopper_converges_on_flat_loss() {
        let mut s = Stopper::new();
        let cfg = FitConfig { tol: 1e-6, ..Default::default() };
        assert!(!s.step(0, 10.0, &cfg));
        assert!(!s.step(1, 9.0, &cfg));
        assert!(s.step(2, 9.0 - 1e-9, &cfg));
        assert!(s.trace.converged);
    }

    #[test]
    fn stopper_flags_divergence() {
        let mut s = Stopper::new();
        let cfg = FitConfig::default();
        assert!(!s.step(0, 10.0, &cfg));
        assert!(s.step(1, f64::INFINITY, &cfg));
        assert!(s.trace.diverged);
    }
}
