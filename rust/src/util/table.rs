//! Plain-text table rendering and CSV writing for the experiment harness.

use std::io::Write;
use std::path::Path;

/// Simple column-aligned table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width must match header width"
        );
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("# {}\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", cells[i], width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// Write as CSV (RFC-4180-ish; quotes cells containing separators).
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        let esc = |c: &str| -> String {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        writeln!(
            f,
            "{}",
            self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
        )?;
        for r in &self.rows {
            writeln!(f, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","))?;
        }
        Ok(())
    }
}

/// Format a float compactly for tables.
pub fn fnum(x: f64) -> String {
    if !x.is_finite() {
        return format!("{x}");
    }
    if x == 0.0 {
        return "0".to_string();
    }
    let a = x.abs();
    if a >= 1e5 || a < 1e-3 {
        format!("{x:.3e}")
    } else {
        format!("{x:.4}")
    }
}

/// mean ± std rendering for CV summaries.
pub fn mean_std(values: &[f64]) -> String {
    if values.is_empty() {
        return "n/a".to_string();
    }
    let n = values.len() as f64;
    let mean = values.iter().sum::<f64>() / n;
    let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
    format!("{}±{}", fnum(mean), fnum(var.sqrt()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("# demo"));
        assert!(s.contains("a  long_header"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_escaping() {
        let dir = std::env::temp_dir().join("fs_table_test");
        let path = dir.join("t.csv");
        let mut t = Table::new("t", &["x", "y"]);
        t.row(vec!["a,b".into(), "c\"d".into()]);
        t.write_csv(&path).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.contains("\"a,b\""));
        assert!(s.contains("\"c\"\"d\""));
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert!(fnum(1234.5).starts_with("1234.5"));
        assert!(fnum(1e-7).contains('e'));
    }

    #[test]
    fn mean_std_format() {
        let s = mean_std(&[1.0, 1.0, 1.0]);
        assert!(s.starts_with("1.0000±0"));
    }
}
