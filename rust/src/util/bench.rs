//! Benchmark harness (no `criterion` offline).
//!
//! `cargo bench` targets use `harness = false` and drive this module:
//! warmup, calibrated iteration counts, robust statistics (median + MAD),
//! and a one-line-per-benchmark report compatible with shell pipelines.

use std::time::{Duration, Instant};

/// Result statistics for one benchmark in nanoseconds per iteration.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub samples: Vec<f64>,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub mad_ns: f64,
    pub iters_per_sample: u64,
}

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len();
    if n == 0 {
        return f64::NAN;
    }
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        0.5 * (xs[n / 2 - 1] + xs[n / 2])
    }
}

/// Human units for a nanosecond quantity.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with a fixed per-benchmark time budget.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(300),
            measure: Duration::from_millis(1500),
            min_samples: 10,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Small-budget bencher for smoke runs (CI, `bench --quick`).
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(250),
            min_samples: 5,
            results: Vec::new(),
        }
    }

    /// Quick-mode bencher (used under `FASTSURVIVAL_BENCH_QUICK=1`, e.g. CI).
    pub fn from_env() -> Self {
        if std::env::var("FASTSURVIVAL_BENCH_QUICK").as_deref() == Ok("1") {
            Self::quick()
        } else {
            Self::default()
        }
    }

    /// Time `f`, which must consume its output (use `std::hint::black_box`).
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Stats {
        // Warmup + calibration: find iters per sample so a sample ~2ms.
        let t0 = Instant::now();
        let mut calib_iters: u64 = 0;
        while t0.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_nanos() as f64 / calib_iters.max(1) as f64;
        let iters_per_sample = ((2e6 / per_iter).ceil() as u64).max(1);

        let mut samples = Vec::new();
        let budget = Instant::now();
        while budget.elapsed() < self.measure || samples.len() < self.min_samples {
            let s = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            samples.push(s.elapsed().as_nanos() as f64 / iters_per_sample as f64);
            if samples.len() > 10_000 {
                break;
            }
        }

        let mut sorted = samples.clone();
        let med = median(&mut sorted);
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let mut devs: Vec<f64> = samples.iter().map(|x| (x - med).abs()).collect();
        let mad = median(&mut devs);

        let stats = Stats {
            name: name.to_string(),
            samples,
            median_ns: med,
            mean_ns: mean,
            min_ns: min,
            mad_ns: mad,
            iters_per_sample,
        };
        println!(
            "bench {:<52} median {:>12}  min {:>12}  ±{:>10}  (n={} x{})",
            stats.name,
            fmt_ns(stats.median_ns),
            fmt_ns(stats.min_ns),
            fmt_ns(stats.mad_ns),
            stats.samples.len(),
            stats.iters_per_sample,
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// All collected stats.
    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Render a closing summary table.
    pub fn summary(&self, title: &str) {
        println!("\n== {title} ==");
        for s in &self.results {
            println!("  {:<52} {:>12}/iter", s.name, fmt_ns(s.median_ns));
        }
    }
}

/// Measure a single closure once (for coarse end-to-end timings in the
/// experiment harness, not microbenches).
pub fn time_once<R>(f: impl FnOnce() -> R) -> (R, Duration) {
    let t = Instant::now();
    let r = f();
    (r, t.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&mut [3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&mut [4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with(" s"));
    }

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(10),
            min_samples: 2,
            results: Vec::new(),
        };
        let mut acc = 0u64;
        b.bench("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].median_ns > 0.0);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 7);
        assert_eq!(v, 7);
        assert!(d.as_nanos() > 0);
    }
}
