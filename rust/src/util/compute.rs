//! Unified compute configuration: kernel backend, thread count, feature
//! precision, and cache-block sizing, resolved **once** per fit.
//!
//! Every scattered `FASTSURVIVAL_THREADS` lookup in the codebase funnels
//! through [`Compute::resolve`]: the environment variable survives only as
//! the default applied here, so a fit can never observe a mid-run change
//! and parallel code stops paying an env lookup per sweep. Requesting an
//! unknown backend or precision is a typed [`FastSurvivalError::Unknown`],
//! never a silent fallback.

use crate::error::{FastSurvivalError, Result};

/// Number of interleaved accumulator lanes used by the SIMD kernels.
///
/// Four independent f64 chains are enough to hide FMA latency on every
/// mainstream x86-64/aarch64 core while keeping the per-tile working set
/// (LANES feature columns + the shared weight column) small enough to
/// block for L2.
pub const LANES: usize = 4;

/// Requested kernel backend. `Auto` resolves to the best backend compiled
/// into this build (always [`KernelBackend::Simd`] — the portable
/// multi-accumulator kernels are std-only Rust and available everywhere).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pick the best available backend at resolve time.
    Auto,
    /// Reference scalar kernels (one accumulator chain per column).
    Scalar,
    /// Portable SIMD: hand-unrolled multi-accumulator lane kernels.
    Simd,
}

impl Backend {
    /// Parse a CLI/user-facing backend name.
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "auto" => Ok(Backend::Auto),
            "scalar" => Ok(Backend::Scalar),
            "simd" => Ok(Backend::Simd),
            _ => Err(FastSurvivalError::Unknown {
                kind: "backend",
                name: name.to_string(),
                expected: "auto|scalar|simd",
            }),
        }
    }
}

/// Feature-matrix storage precision. Accumulation is always f64; this
/// controls only how matrix *cells* are stored (in memory and on disk).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// Full f64 cells (default; bitwise-compatible with every prior release).
    F64,
    /// f32 cell storage with f64 accumulation: halves feature bandwidth.
    /// Fits agree with F64 to ≤1e-6 per coefficient (storage quantization).
    F32Storage,
}

impl Precision {
    /// Parse a CLI/user-facing precision name.
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "f64" => Ok(Precision::F64),
            "f32" => Ok(Precision::F32Storage),
            _ => Err(FastSurvivalError::Unknown {
                kind: "precision",
                name: name.to_string(),
                expected: "f64|f32",
            }),
        }
    }

    /// Stable display name (matches `from_name` input).
    pub fn name(&self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32Storage => "f32",
        }
    }
}

/// Cache-block row-tile size for the batched derivative kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockRows {
    /// Size the row tile from the problem shape (targets ~256 KiB of hot
    /// working set so the shared weight column stays L2-resident across
    /// lane groups).
    Auto,
    /// Fixed row-tile size (floored at 64 rows).
    Fixed(usize),
}

/// User-facing compute request. Build one, hand it to
/// `CoxFit::compute(...)` (or the CLI `--backend/--threads/--precision`
/// flags), and it is resolved exactly once when the fit starts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Compute {
    pub backend: Backend,
    /// `None` → the `FASTSURVIVAL_THREADS` env default (then core count).
    pub threads: Option<usize>,
    pub precision: Precision,
    pub block_rows: BlockRows,
}

impl Default for Compute {
    fn default() -> Self {
        Compute {
            backend: Backend::Auto,
            threads: None,
            precision: Precision::F64,
            block_rows: BlockRows::Auto,
        }
    }
}

impl Compute {
    /// Set the kernel backend.
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Pin the worker-thread count (overrides the env default).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Set the feature-matrix storage precision.
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Override the autotuned cache-block row-tile size.
    pub fn block_rows(mut self, rows: usize) -> Self {
        self.block_rows = BlockRows::Fixed(rows);
        self
    }

    /// Build the shared compute request from the CLI flags `--backend`,
    /// `--threads`, `--precision`, and `--block-rows`. Unknown names and
    /// invalid counts surface as typed errors when the consuming engine
    /// resolves the request — exactly once per fit, never a silent
    /// fallback.
    pub fn from_args(args: &crate::util::args::Args) -> Result<Self> {
        let mut c = Compute::default();
        if let Some(b) = args.get("backend") {
            c = c.backend(Backend::from_name(b)?);
        }
        if args.get("threads").is_some() {
            c = c.threads(args.get_or("threads", 0usize));
        }
        if let Some(p) = args.get("precision") {
            c = c.precision(Precision::from_name(p)?);
        }
        if args.get("block-rows").is_some() {
            c = c.block_rows(args.get_or("block-rows", 0usize));
        }
        Ok(c)
    }

    /// Resolve the request into concrete settings. This is the **only**
    /// place in the crate that reads `FASTSURVIVAL_THREADS`.
    pub fn resolve(&self) -> Result<ResolvedCompute> {
        let backend = match self.backend {
            // Both backends are compiled into every std-only build, so
            // Auto always lands on the faster one. An unknown *name* is
            // rejected upstream by `Backend::from_name`.
            Backend::Auto | Backend::Simd => KernelBackend::Simd,
            Backend::Scalar => KernelBackend::Scalar,
        };
        let threads = match self.threads {
            Some(0) => {
                return Err(FastSurvivalError::InvalidConfig(
                    "compute.threads must be >= 1".to_string(),
                ))
            }
            Some(n) => n,
            None => env_threads(),
        };
        Ok(ResolvedCompute {
            backend,
            threads,
            precision: self.precision,
            block_rows: self.block_rows,
        })
    }
}

/// A concrete kernel backend (post-`Auto` resolution). Every hot-path
/// kernel in `cox/` dispatches on this; both variants satisfy the same
/// contract — per-column accumulation order is identical, so batched
/// derivatives and coordinate updates are **bitwise** equal across
/// backends, and the reassociated single-column reductions agree to
/// ≤1e-12.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelBackend {
    Scalar,
    Simd,
}

impl KernelBackend {
    /// Stable display name (bench rows, logs).
    pub fn name(&self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Simd => "simd",
        }
    }
}

/// Fully resolved compute settings, captured once at fit start and
/// threaded through every kernel call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResolvedCompute {
    pub backend: KernelBackend,
    pub threads: usize,
    pub precision: Precision,
    pub block_rows: BlockRows,
}

impl ResolvedCompute {
    /// Resolve the ambient default request (env-driven thread count, Auto
    /// backend). Infallible: the default `Compute` has no invalid fields.
    pub fn ambient() -> Self {
        Compute::default().resolve().expect("default compute always resolves")
    }

    /// Concrete row-tile size for a problem with `n` rows.
    pub fn block_rows_for(&self, n: usize) -> usize {
        match self.block_rows {
            BlockRows::Fixed(b) => b.max(64),
            BlockRows::Auto => auto_block_rows(n),
        }
    }
}

/// Default kernel backend used by legacy (non-`Compute`-aware) call
/// paths, so every default route runs one uniform backend and the
/// cross-path bitwise contracts keep holding.
pub fn default_backend() -> KernelBackend {
    KernelBackend::Simd
}

/// Autotuned cache-block row-tile size: target ~256 KiB of hot working
/// set per tile (LANES f64 feature columns + the shared weight column per
/// row), clamped to [1024, 16384]. Depends only on the problem shape —
/// never on thread count — so blocked results stay bitwise invariant
/// across `threads`.
pub fn auto_block_rows(n: usize) -> usize {
    const TARGET_BYTES: usize = 256 * 1024;
    const BYTES_PER_ROW: usize = (LANES + 1) * 8;
    let tile = (TARGET_BYTES / BYTES_PER_ROW).clamp(1024, 16384);
    tile.min(n.max(1))
}

/// Ambient worker-thread default: `FASTSURVIVAL_THREADS` if set and
/// valid, else the machine's available parallelism. The env lookup lives
/// here (and only here) so [`Compute::resolve`] is the one read site.
pub(crate) fn env_threads() -> usize {
    if let Ok(v) = std::env::var("FASTSURVIVAL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_names_round_trip() {
        assert_eq!(Backend::from_name("auto").unwrap(), Backend::Auto);
        assert_eq!(Backend::from_name("scalar").unwrap(), Backend::Scalar);
        assert_eq!(Backend::from_name("simd").unwrap(), Backend::Simd);
        let err = Backend::from_name("avx512").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("backend"), "typed error names the kind: {msg}");
        assert!(msg.contains("avx512"), "typed error echoes the name: {msg}");
    }

    #[test]
    fn precision_names_round_trip() {
        assert_eq!(Precision::from_name("f64").unwrap(), Precision::F64);
        assert_eq!(Precision::from_name("f32").unwrap(), Precision::F32Storage);
        assert!(Precision::from_name("f16").is_err());
        assert_eq!(Precision::F32Storage.name(), "f32");
    }

    #[test]
    fn resolve_applies_overrides() {
        let rc = Compute::default()
            .backend(Backend::Scalar)
            .threads(3)
            .precision(Precision::F32Storage)
            .resolve()
            .unwrap();
        assert_eq!(rc.backend, KernelBackend::Scalar);
        assert_eq!(rc.threads, 3);
        assert_eq!(rc.precision, Precision::F32Storage);
    }

    #[test]
    fn zero_threads_is_a_typed_error() {
        let err = Compute::default().threads(0).resolve().unwrap_err();
        assert!(matches!(err, FastSurvivalError::InvalidConfig(_)));
    }

    #[test]
    fn auto_resolves_to_simd() {
        let rc = Compute::default().resolve().unwrap();
        assert_eq!(rc.backend, KernelBackend::Simd);
        assert!(rc.threads >= 1);
    }

    #[test]
    fn auto_block_rows_is_shape_only_and_clamped() {
        assert_eq!(auto_block_rows(50_000), auto_block_rows(50_000));
        assert!(auto_block_rows(1_000_000) <= 16_384);
        assert!(auto_block_rows(1_000_000) >= 1024);
        // Tiny problems never get a tile larger than the problem.
        assert_eq!(auto_block_rows(100), 100);
        assert_eq!(auto_block_rows(0), 1);
    }

    #[test]
    fn fixed_block_rows_is_floored() {
        let rc = Compute::default().block_rows(8).resolve().unwrap();
        assert_eq!(rc.block_rows_for(1_000_000), 64);
        let rc = Compute::default().block_rows(2048).resolve().unwrap();
        assert_eq!(rc.block_rows_for(1_000_000), 2048);
    }
}
