//! Minimal property-based testing harness (no `proptest` crate offline).
//!
//! [`check`] runs a property over many randomized cases drawn from a
//! generator; on failure it reports the seed and case index so the exact
//! failing input can be regenerated deterministically. [`check_shrink`]
//! additionally performs greedy shrinking when the case type supports it.

use crate::util::rng::Rng;

/// Default number of randomized cases per property.
pub const DEFAULT_CASES: usize = 64;

/// Run `prop` on `cases` inputs drawn by `gen`. Panics with seed + case
/// index on the first counterexample.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = rng.fork(case as u64);
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed (seed={seed}, case={case}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

/// Like [`check`], but greedily shrinks the failing case with `shrink`
/// (which returns smaller candidate inputs) before reporting.
pub fn check_shrink<T: std::fmt::Debug + Clone>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = rng.fork(case as u64);
        let input = gen(&mut case_rng);
        if let Err(first_msg) = prop(&input) {
            // Greedy shrink: keep taking the first failing smaller candidate.
            let mut current = input.clone();
            let mut msg = first_msg;
            'outer: loop {
                for cand in shrink(&current) {
                    if let Err(m) = prop(&cand) {
                        current = cand;
                        msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property {name:?} failed (seed={seed}, case={case}): {msg}\nshrunk input: {current:#?}"
            );
        }
    }
}

/// Generator helpers used by the cox/optim property tests.
pub mod gen {
    use crate::util::rng::Rng;

    /// Random vector of length in [lo, hi] with N(0,1) entries.
    pub fn normal_vec(rng: &mut Rng, lo: usize, hi: usize) -> Vec<f64> {
        let n = lo + rng.below(hi - lo + 1);
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Random vector of fixed length with entries in [lo, hi).
    pub fn uniform_vec(rng: &mut Rng, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| rng.uniform_range(lo, hi)).collect()
    }

    /// Random event indicators with at least one event.
    pub fn events(rng: &mut Rng, n: usize, p_event: f64) -> Vec<bool> {
        let mut d: Vec<bool> = (0..n).map(|_| rng.bernoulli(p_event)).collect();
        if !d.iter().any(|&x| x) {
            let i = rng.below(n);
            d[i] = true;
        }
        d
    }

    /// Random observation times, possibly with ties (quantized).
    pub fn times(rng: &mut Rng, n: usize, with_ties: bool) -> Vec<f64> {
        (0..n)
            .map(|_| {
                let t = rng.uniform_range(0.1, 10.0);
                if with_ties {
                    (t * 4.0).round() / 4.0
                } else {
                    t
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "sum-nonneg",
            1,
            32,
            |r| gen::uniform_vec(r, 8, 0.0, 1.0),
            |xs| {
                if xs.iter().sum::<f64>() >= 0.0 {
                    Ok(())
                } else {
                    Err("negative".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\" failed")]
    fn failing_property_reports() {
        check("always-fails", 2, 4, |r| r.uniform(), |_| Err("nope".into()));
    }

    #[test]
    #[should_panic(expected = "shrunk input: 0")]
    fn shrinking_minimizes() {
        // Property "x < 0" fails for any u64; shrinker halves toward 0, so
        // the reported counterexample must be exactly 0.
        check_shrink(
            "lt-zero",
            3,
            1,
            |r| r.below(1000) as u64 + 1,
            |&x| if x > 0 { vec![x / 2, x - 1] } else { vec![] },
            |&x| if x < u64::MAX { Err(format!("x={x}")) } else { Ok(()) },
        );
    }

    #[test]
    fn events_always_has_event() {
        let mut r = Rng::new(4);
        for _ in 0..50 {
            let d = gen::events(&mut r, 10, 0.01);
            assert!(d.iter().any(|&x| x));
        }
    }
}
