//! Tiny command-line argument parser (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments. Typed getters parse on demand and report readable errors.

use std::collections::BTreeMap;

/// Parsed command line: positionals plus `--key [value]` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse an iterator of raw arguments (without argv\[0\]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another option
                    // or missing, in which case it is a bare flag.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.options.insert(stripped.to_string(), v);
                        }
                        _ => out.flags.push(stripped.to_string()),
                    }
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse the process command line.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Raw option value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Bare flag present (`--verbose`)? Options with values also count.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.options.contains_key(key)
    }

    /// String option with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option with default; panics with a readable message on a
    /// malformed value (CLI boundary, so panicking is the right behavior).
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => default,
            Some(v) => v
                .parse::<T>()
                .unwrap_or_else(|e| panic!("invalid value for --{key}: {v:?} ({e})")),
        }
    }

    /// Required typed option.
    pub fn require<T: std::str::FromStr>(&self, key: &str) -> T
    where
        T::Err: std::fmt::Display,
    {
        let v = self
            .get(key)
            .unwrap_or_else(|| panic!("missing required option --{key}"));
        v.parse::<T>()
            .unwrap_or_else(|e| panic!("invalid value for --{key}: {v:?} ({e})"))
    }

    /// Comma-separated list of T.
    pub fn list_or<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Vec<T>
    where
        T::Err: std::fmt::Display,
        T: Clone,
    {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse::<T>()
                        .unwrap_or_else(|e| panic!("invalid list item for --{key}: {s:?} ({e})"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positionals_and_options() {
        let a = parse(&["fit", "--dataset", "flchain", "--l2=5.0", "--verbose"]);
        assert_eq!(a.positional, vec!["fit"]);
        assert_eq!(a.get("dataset"), Some("flchain"));
        assert_eq!(a.get_or::<f64>("l2", 0.0), 5.0);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["run"]);
        assert_eq!(a.get_or::<usize>("iters", 10), 10);
        assert_eq!(a.str_or("method", "cubic"), "cubic");
    }

    #[test]
    fn negative_numbers_are_values() {
        // "-1.5" does not start with "--" so it is consumed as a value.
        let a = parse(&["--shift", "-1.5"]);
        assert_eq!(a.get_or::<f64>("shift", 0.0), -1.5);
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["--ks", "1,2,5"]);
        assert_eq!(a.list_or::<usize>("ks", &[9]), vec![1, 2, 5]);
        assert_eq!(a.list_or::<usize>("absent", &[9]), vec![9]);
    }

    #[test]
    #[should_panic(expected = "missing required option")]
    fn require_missing_panics() {
        let a = parse(&[]);
        let _: usize = a.require("k");
    }

    #[test]
    fn flag_followed_by_option() {
        let a = parse(&["--dry-run", "--k", "3"]);
        assert!(a.flag("dry-run"));
        assert_eq!(a.get_or::<usize>("k", 0), 3);
    }
}
