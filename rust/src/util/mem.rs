//! Process memory introspection for the out-of-core memory gates.
//!
//! The `bigfit` benchmark promises a peak-RSS bound well below the
//! dataset's in-memory footprint; these helpers read the numbers the
//! kernel already tracks (`/proc/self/status` on Linux). On platforms
//! without procfs they return `None` and callers report the gate as
//! skipped rather than failing spuriously.

/// Peak resident set size of this process in bytes (`VmHWM`), if the
/// platform exposes it. Monotone over the process lifetime: it covers
/// every allocation made so far, which is exactly what a "never held the
/// matrix in RAM" gate needs.
pub fn peak_rss_bytes() -> Option<u64> {
    read_status_kb("VmHWM:")
}

/// Current resident set size in bytes (`VmRSS`), if available.
pub fn current_rss_bytes() -> Option<u64> {
    read_status_kb("VmRSS:")
}

fn read_status_kb(key: &str) -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(key) {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb.saturating_mul(1024));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_readings_are_sane_when_available() {
        // On Linux both must parse and peak must dominate current; on
        // platforms without procfs both are None and the gate is skipped.
        match (current_rss_bytes(), peak_rss_bytes()) {
            (Some(cur), Some(peak)) => {
                assert!(cur > 0);
                assert!(peak >= cur / 2, "peak {peak} vs current {cur}");
            }
            (None, None) => {}
            other => panic!("inconsistent procfs readings: {other:?}"),
        }
    }
}
