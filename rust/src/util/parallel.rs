//! Scoped data-parallel helpers over `std::thread` (no tokio offline).
//!
//! The experiment harness fans out independent work items (CV folds,
//! figure cells, bootstrap trees) across cores; everything here is
//! fork-join with deterministic output ordering.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// Ambient worker-thread default. Delegates to `util::compute` — the one
/// place allowed to read `FASTSURVIVAL_THREADS`. Prefer resolving a
/// [`crate::util::compute::Compute`] once per fit over calling this in a
/// loop.
pub fn num_threads() -> usize {
    crate::util::compute::env_threads()
}

/// Map `f` over `items` in parallel, preserving input order in the output.
///
/// Work stealing is a shared atomic cursor; each worker grabs the next
/// index. `f` must be `Sync` (called concurrently) and items are accessed
/// by shared reference.
pub fn par_map<T: Sync, R: Send, F: Fn(&T) -> R + Sync>(items: &[T], f: F) -> Vec<R> {
    par_map_workers(items, num_threads(), f)
}

/// [`par_map`] with an explicit worker count, for callers (benchmarks,
/// parity tests) that must pin parallelism independently of
/// `FASTSURVIVAL_THREADS`. Output order — and, because each item is
/// processed in isolation, every result bit — is identical for every
/// worker count.
pub fn par_map_workers<T: Sync, R: Send, F: Fn(&T) -> R + Sync>(
    items: &[T],
    workers: usize,
    f: F,
) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers <= 1 {
        return items.iter().map(&f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

/// Parallel map over an index range 0..n.
pub fn par_map_indices<R: Send, F: Fn(usize) -> R + Sync>(n: usize, f: F) -> Vec<R> {
    let idx: Vec<usize> = (0..n).collect();
    par_map(&idx, |&i| f(i))
}

/// Apply `f(index, &mut item)` to every item in parallel, mutating in
/// place. Items are split into contiguous chunks, one worker per chunk,
/// so each worker owns a disjoint `&mut` slice (no locking). Used by the
/// stratified fit to advance every per-stratum state after a shared-β
/// coordinate step.
pub fn par_for_each_mut<T: Send, F: Fn(usize, &mut T) + Sync>(items: &mut [T], f: F) {
    par_for_each_mut_workers(items, num_threads(), f)
}

/// [`par_for_each_mut`] with an explicit worker count, for callers that
/// resolved their thread budget once up front (e.g. the stratified fit's
/// `Compute`) and must not re-read the environment per invocation.
pub fn par_for_each_mut_workers<T: Send, F: Fn(usize, &mut T) + Sync>(
    items: &mut [T],
    workers: usize,
    f: F,
) {
    let n = items.len();
    if n == 0 {
        return;
    }
    let workers = workers.max(1).min(n);
    if workers <= 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let chunk = (n + workers - 1) / workers;
    std::thread::scope(|scope| {
        for (ci, part) in items.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || {
                for (j, item) in part.iter_mut().enumerate() {
                    f(ci * chunk + j, item);
                }
            });
        }
    });
}

/// Split `0..total` into at most `workers` contiguous, near-even,
/// non-empty half-open ranges covering the whole span in order. Used by
/// the sharded fit engine to hand each worker an ownership range of
/// merge tiles; results there are partition-invariant, so the exact
/// split only affects load balance, never the answer. `total == 0`
/// yields a single empty range.
pub(crate) fn contiguous_ranges(total: usize, workers: usize) -> Vec<(usize, usize)> {
    if total == 0 {
        return vec![(0, 0)];
    }
    let workers = workers.max(1).min(total);
    let (base, extra) = (total / workers, total % workers);
    let mut ranges = Vec::with_capacity(workers);
    let mut start = 0;
    for r in 0..workers {
        let len = base + usize::from(r < extra);
        ranges.push((start, start + len));
        start += len;
    }
    ranges
}

/// A long-lived pool of named worker threads consuming boxed jobs from a
/// shared queue. Unlike the fork-join helpers above (which spawn scoped
/// threads per call), the pool amortizes thread startup across many
/// irregular tasks — the scoring server hands it one job per accepted
/// connection. Dropping the pool closes the queue, lets every queued job
/// finish, and joins the workers (graceful drain, nothing is abandoned).
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Box<dyn FnOnce() + Send + 'static>>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` threads named `{name}-{i}`.
    pub fn new(workers: usize, name: &str) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Box<dyn FnOnce() + Send + 'static>>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let rx = Arc::clone(&rx);
            let handle = std::thread::Builder::new()
                .name(format!("{name}-{i}"))
                .spawn(move || loop {
                    // Hold the lock only for the dequeue, not the job.
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // queue closed: pool is dropping
                    }
                })
                .expect("failed to spawn worker thread");
            handles.push(handle);
        }
        WorkerPool { tx: Some(tx), handles }
    }

    /// Enqueue a job. Jobs run in FIFO order as workers free up; after
    /// the pool has been dropped this is a silent no-op.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        if let Some(tx) = &self.tx {
            let _ = tx.send(Box::new(job));
        }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // close the queue; workers exit once it drains
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = par_map(&items, |&x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let items: Vec<usize> = vec![];
        let out: Vec<usize> = par_map(&items, |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn single_item() {
        let out = par_map(&[41usize], |&x| x + 1);
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn indices_helper() {
        let out = par_map_indices(10, |i| i * i);
        assert_eq!(out[9], 81);
    }

    #[test]
    fn explicit_worker_counts_agree() {
        let items: Vec<usize> = (0..37).collect();
        let expect: Vec<usize> = items.iter().map(|&x| x * 3 + 1).collect();
        for workers in [1usize, 2, 4, 8] {
            let out = par_map_workers(&items, workers, |&x| x * 3 + 1);
            assert_eq!(out, expect, "workers={workers}");
        }
    }

    #[test]
    fn for_each_mut_touches_every_item_once() {
        let mut items = vec![0usize; 100];
        par_for_each_mut(&mut items, |i, v| *v = i + 1);
        for (i, v) in items.iter().enumerate() {
            assert_eq!(*v, i + 1);
        }
        // Empty slice is a no-op, not a panic.
        let mut empty: Vec<usize> = Vec::new();
        par_for_each_mut(&mut empty, |_, _| unreachable!());
    }

    #[test]
    fn contiguous_ranges_cover_and_balance() {
        for (total, workers) in [(10usize, 3usize), (7, 7), (5, 9), (1, 4), (16, 4)] {
            let ranges = contiguous_ranges(total, workers);
            assert!(ranges.len() <= workers.max(1));
            let mut next = 0;
            for &(a, b) in &ranges {
                assert_eq!(a, next, "total={total} workers={workers}");
                assert!(b > a, "ranges must be non-empty");
                next = b;
            }
            assert_eq!(next, total);
            let (min, max) = ranges
                .iter()
                .fold((usize::MAX, 0), |(lo, hi), &(a, b)| (lo.min(b - a), hi.max(b - a)));
            assert!(max - min <= 1, "near-even split");
        }
        assert_eq!(contiguous_ranges(0, 4), vec![(0, 0)]);
    }

    #[test]
    fn worker_pool_runs_every_job_and_drains_on_drop() {
        use std::sync::atomic::AtomicUsize;
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = WorkerPool::new(3, "test-pool");
            assert_eq!(pool.workers(), 3);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop joins after the queue drains.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn heavy_items_all_complete() {
        let items: Vec<u64> = (0..64).collect();
        let out = par_map(&items, |&x| {
            // small CPU-bound task
            let mut acc = x;
            for i in 0..1000 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            acc
        });
        assert_eq!(out.len(), 64);
    }
}
