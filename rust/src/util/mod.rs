//! Small infrastructure substrates.
//!
//! The offline build has no access to `rand`, `clap`, `criterion`,
//! `proptest`, or `serde`, so this module provides minimal, well-tested
//! in-repo replacements: a PRNG, an argument parser, a scoped thread pool,
//! a property-testing helper, a benchmark harness, and a table renderer.

pub mod args;
pub mod bench;
pub mod compute;
pub mod mem;
pub mod parallel;
pub mod proptest;
pub mod rng;
pub mod table;
