//! Deterministic pseudo-random number generation.
//!
//! `xoshiro256**` seeded through SplitMix64, plus the distribution helpers
//! the experiments need (uniform, normal via Box–Muller, permutation,
//! subsampling). No external `rand` crate is available offline.

/// xoshiro256** generator (Blackman & Vigna). Deterministic, splittable
/// via [`Rng::fork`], passes BigCrush; plenty for simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box–Muller.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-fold / per-thread use).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (caches the spare deviate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to keep ln finite.
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Standard exponential deviate.
    pub fn exponential(&mut self) -> f64 {
        -(1.0 - self.uniform()).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `k` distinct indices from 0..n (k <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut p = self.permutation(n);
        p.truncate(k);
        p
    }

    /// Sample `k` indices from 0..n with replacement (bootstrap).
    pub fn sample_with_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.below(n)).collect()
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(9);
        let p = r.permutation(57);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..57).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let s = r.sample_indices(100, 30);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 30);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(21);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let xa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }

    #[test]
    fn exponential_positive_mean_one() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential()).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
    }
}
