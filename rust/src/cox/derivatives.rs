//! Exact O(n) partial derivatives (Theorem 3.1 / Corollary 3.3) plus the
//! η-space quantities the Newton baselines need.
//!
//! Key observation: with samples sorted by descending time, every risk
//! set is a prefix, so the weighted power sums
//! `S_r(i) = Σ_{k∈R_i} w_k x_{kl}^r` for r = 0..3 are running prefix sums.
//! All events within a tie group share one risk set, so each group
//! contributes its moment expression once, scaled by its event count.

use super::kernels;
use super::problem::{CoxProblem, TieGroup};
use super::state::CoxState;
use crate::linalg::Matrix;
use crate::util::compute::{auto_block_rows, default_backend, KernelBackend};
use crate::util::parallel::{num_threads, par_map_indices, par_map_workers};

/// First/second/third partial derivatives at one coordinate.
#[derive(Clone, Copy, Debug, Default)]
pub struct CoordDerivs {
    pub d1: f64,
    pub d2: f64,
    pub d3: f64,
}

/// Columns per parallel task in the blocked batched pass: big enough to
/// amortize dispatch, small enough that p in the hundreds load-balances
/// across a handful of workers.
const COL_BLOCK: usize = 8;

/// Memory cap (in f64 slots) for materializing the per-event-group
/// prefix vectors of the blocked β-Hessian; past it the streaming
/// sequential kernel runs instead.
const HESSIAN_V_CAP: usize = 8_000_000;

/// Minimum n·p before a batched pass is worth a scoped-thread spawn
/// (below this the fork-join overhead dominates the numeric work).
const PAR_MIN_WORK: usize = 1 << 15;

/// Reusable buffers + the per-η-update risk-set weight cache shared by
/// every batched pass.
///
/// The cache is keyed on [`CoxState::version`]: [`Workspace::prepare`]
/// recomputes the per-group prefix weights only when the state actually
/// changed, so any number of coordinate passes at one η share a single
/// O(n) prefix accumulation — and the per-column loops run with zero
/// divisions (1/S0 is hoisted here). A workspace may serve many states
/// interchangeably (the beam-search pattern); version tags are globally
/// unique so stale hits cannot happen.
#[derive(Default, Debug)]
pub struct Workspace {
    /// Per-group 1/S0 (S0(g) = Σ_{k < end_g} w_k) — divisions hoisted
    /// out of the per-column loops.
    group_inv_s0: Vec<f64>,
    /// Per-group risk-set weight n_events/S0 (Theorem 3.1).
    group_weight: Vec<f64>,
    /// Suffix sums A(g) = Σ_{g' ≥ g} n_events/S0 (η-gradient weights).
    suffix_a: Vec<f64>,
    /// Suffix sums B(g) = Σ_{g' ≥ g} n_events/S0² (η-Hessian weights).
    suffix_b: Vec<f64>,
    /// State version the caches above were built for.
    cached: Option<u64>,
    /// Kernel backend the caches above were built with (the lane-summed
    /// prefix differs ≤1e-12 from the scalar one under heavy ties, so a
    /// backend switch at the same η must rebuild).
    cached_backend: Option<KernelBackend>,
    /// Last version seen by a `_ws` entry point; a second evaluation at
    /// the same η promotes it to a full cache build.
    last_seen: Option<u64>,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// True when the cached weights were built for exactly this state
    /// and kernel backend.
    #[inline]
    fn is_fresh_b(&self, state: &CoxState, backend: KernelBackend) -> bool {
        self.cached == Some(state.version()) && self.cached_backend == Some(backend)
    }

    /// (Re)build the per-group weights for `state` if stale: one O(n)
    /// prefix pass plus one O(#groups) suffix pass on a miss, O(1) on a
    /// hit. Uses the crate default backend; see [`Workspace::prepare_b`].
    pub fn prepare(&mut self, problem: &CoxProblem, state: &CoxState) {
        self.prepare_b(problem, state, default_backend())
    }

    /// [`Workspace::prepare`] with an explicit kernel backend: the SIMD
    /// arm lane-sums the within-group weight partials for tie groups of
    /// ≥8 samples (reassociation ≤1e-12); singleton groups — all of them
    /// on continuous data — take the scalar path bit for bit.
    pub fn prepare_b(&mut self, problem: &CoxProblem, state: &CoxState, backend: KernelBackend) {
        if self.is_fresh_b(state, backend) {
            crate::obs::counters::workspace_cache(true);
            return;
        }
        crate::obs::counters::workspace_cache(false);
        let _span = crate::obs::SpanTimer::start(crate::obs::Phase::WorkspacePrepare);
        let ngroups = problem.groups.len();
        self.group_inv_s0.clear();
        self.group_inv_s0.reserve(ngroups);
        self.group_weight.clear();
        self.group_weight.reserve(ngroups);
        let mut s0 = 0.0_f64;
        for g in &problem.groups {
            if backend == KernelBackend::Simd && g.end - g.start >= kernels::LANE_MIN {
                s0 += kernels::sum1(&state.w[g.start..g.end]);
            } else {
                for k in g.start..g.end {
                    s0 += state.w[k];
                }
            }
            let inv = 1.0 / s0;
            self.group_inv_s0.push(inv);
            self.group_weight.push(g.n_events as f64 * inv);
        }
        self.suffix_a.clear();
        self.suffix_a.resize(ngroups, 0.0);
        self.suffix_b.clear();
        self.suffix_b.resize(ngroups, 0.0);
        let (mut sa, mut sb) = (0.0_f64, 0.0_f64);
        for gi in (0..ngroups).rev() {
            let ne = problem.groups[gi].n_events as f64;
            let inv = self.group_inv_s0[gi];
            sa += ne * inv;
            sb += ne * inv * inv;
            self.suffix_a[gi] = sa;
            self.suffix_b[gi] = sb;
        }
        self.cached = Some(state.version());
        self.cached_backend = Some(backend);
        self.last_seen = Some(state.version());
    }

    /// The cached per-group weights as slices `(1/S0, ne/S0)` — handed to
    /// the batched lane kernel, which runs outside `self` so column
    /// blocks can fan out while the cache is shared immutably.
    pub(crate) fn cache_parts(&self) -> (&[f64], &[f64]) {
        (&self.group_inv_s0, &self.group_weight)
    }

    /// d1 at one coordinate from the cached suffix weights:
    /// `d1 = Σ_k w_k x_kl A(g(k)) − (Xᵀδ)_l` — a single fused multiply
    /// pass, no divisions, no per-group branching. Requires `prepare`.
    /// The SIMD backend runs the same reduction on four independent
    /// accumulator chains (reassociated ≤1e-12 — this pass has no
    /// per-group emissions to respect).
    fn coord_d1_from_cache(
        &self,
        problem: &CoxProblem,
        state: &CoxState,
        l: usize,
        backend: KernelBackend,
    ) -> f64 {
        let col = problem.x.col(l);
        match backend {
            KernelBackend::Simd => {
                kernels::weighted_suffix_dot(&state.w, col, &problem.group_of, &self.suffix_a)
                    - problem.xt_delta[l]
            }
            KernelBackend::Scalar => {
                let mut acc = 0.0_f64;
                for ((&wk, &x), &g) in state.w.iter().zip(col).zip(problem.group_of.iter()) {
                    acc += wk * x * self.suffix_a[g];
                }
                acc - problem.xt_delta[l]
            }
        }
    }

    /// (d1, d2) at one coordinate with the cached 1/S0 weights — the
    /// per-column kernel of the blocked batched pass (both backends: the
    /// running prefix emits at every event group, so there is nothing to
    /// reassociate; the SIMD batched pass instead interleaves columns in
    /// [`kernels::batched_d1_d2_block`], bitwise-equal per column to
    /// this). Requires `prepare`.
    fn coord_d1_d2_from_cache(
        &self,
        problem: &CoxProblem,
        state: &CoxState,
        l: usize,
    ) -> (f64, f64) {
        kernels::cached_col_d1_d2(
            &problem.groups,
            &state.w,
            problem.x.col(l),
            problem.xt_delta[l],
            &self.group_inv_s0,
            &self.group_weight,
        )
    }
}

/// d1 only (Eq. 7). One fused pass; the cheapest quantity the quadratic
/// surrogate needs per coordinate update.
pub fn coord_d1(problem: &CoxProblem, state: &CoxState, l: usize) -> f64 {
    coord_d1_col(&problem.groups, &state.w, problem.x.col(l), problem.xt_delta[l])
}

/// [`coord_d1`] from explicit risk-set parts (tie groups, stabilized
/// weights, a column slice, and that column's Xᵀδ entry) instead of a
/// [`CoxProblem`]. The out-of-core driver streams columns from disk and
/// calls this with the identical accumulation order, so chunked and
/// in-memory derivative passes are bit-for-bit the same computation.
pub fn coord_d1_col(groups: &[TieGroup], w: &[f64], col: &[f64], xt_delta_l: f64) -> f64 {
    coord_d1_col_b(default_backend(), groups, w, col, xt_delta_l)
}

/// [`coord_d1_col`] with an explicit kernel backend. The SIMD arm
/// lane-sums within tie groups of ≥8 samples only (the running prefix
/// emits at every event group, so cross-group unrolling would change
/// results); on continuous data both backends are bitwise equal, under
/// heavy ties they agree to ≤1e-12.
pub fn coord_d1_col_b(
    backend: KernelBackend,
    groups: &[TieGroup],
    w: &[f64],
    col: &[f64],
    xt_delta_l: f64,
) -> f64 {
    let (mut s0, mut s1) = (0.0_f64, 0.0_f64);
    let mut d1 = 0.0_f64;
    for g in groups {
        if backend == KernelBackend::Simd && g.end - g.start >= kernels::LANE_MIN {
            let (gs0, gs1) = kernels::sum2(&w[g.start..g.end], &col[g.start..g.end]);
            s0 += gs0;
            s1 += gs1;
        } else {
            for k in g.start..g.end {
                let wk = w[k];
                s0 += wk;
                s1 += wk * col[k];
            }
        }
        if g.n_events > 0 {
            d1 += g.n_events as f64 * (s1 / s0);
        }
    }
    d1 - xt_delta_l
}

/// d1 and d2 (Eqs. 7–8). Used by the cubic surrogate and by screening.
pub fn coord_d1_d2(problem: &CoxProblem, state: &CoxState, l: usize) -> (f64, f64) {
    coord_d1_d2_col(&problem.groups, &state.w, problem.x.col(l), problem.xt_delta[l])
}

/// [`coord_d1_d2`] from explicit risk-set parts; see [`coord_d1_col`].
pub fn coord_d1_d2_col(
    groups: &[TieGroup],
    w: &[f64],
    col: &[f64],
    xt_delta_l: f64,
) -> (f64, f64) {
    coord_d1_d2_col_b(default_backend(), groups, w, col, xt_delta_l)
}

/// [`coord_d1_d2_col`] with an explicit kernel backend; same tolerance
/// contract as [`coord_d1_col_b`].
pub fn coord_d1_d2_col_b(
    backend: KernelBackend,
    groups: &[TieGroup],
    w: &[f64],
    col: &[f64],
    xt_delta_l: f64,
) -> (f64, f64) {
    let (mut s0, mut s1, mut s2) = (0.0_f64, 0.0_f64, 0.0_f64);
    let (mut d1, mut d2) = (0.0_f64, 0.0_f64);
    for g in groups {
        if backend == KernelBackend::Simd && g.end - g.start >= kernels::LANE_MIN {
            let (gs0, gs1, gs2) = kernels::sum3(&w[g.start..g.end], &col[g.start..g.end]);
            s0 += gs0;
            s1 += gs1;
            s2 += gs2;
        } else {
            for k in g.start..g.end {
                let wk = w[k];
                let x = col[k];
                s0 += wk;
                s1 += wk * x;
                s2 += wk * x * x;
            }
        }
        if g.n_events > 0 {
            let ne = g.n_events as f64;
            let m1 = s1 / s0;
            let m2 = s2 / s0;
            d1 += ne * m1;
            d2 += ne * (m2 - m1 * m1);
        }
    }
    (d1 - xt_delta_l, d2)
}

// --------------------------------------------------------------------
// Mergeable tiled kernels: exact risk-set merging for sharded fitting.
//
// The flat kernels above carry one running prefix (S0, S1[, S2]) across
// all n rows, so their floating-point result depends on every prior row
// — a partition across shard workers cannot reproduce it bitwise. The
// tiled kernels below fix a CANONICAL decomposition instead: tie-group-
// aligned row tiles of [`MERGE_TILE_ROWS`] samples (data-derived only —
// never shard count, worker count, or thread count). Per column:
//
//   Phase A (parallelizable per tile): per-group power-sum subtotals
//     accumulated from zero, plus each tile's component-wise total.
//   Carry fold (serial, O(#tiles)): exclusive prefix of tile totals in
//     tile order — the only serial work, ~n/4096 additions.
//   Phase B (parallelizable per tile): replay the running prefix inside
//     the tile as carry + local prefix, emitting event-group
//     contributions into per-tile accumulators from zero.
//   Final fold (serial, O(#tiles)): per-tile emissions in tile order.
//
// Every operation is pinned to a tile or to the canonical tile order, so
// ANY partition of whole tiles across workers — including the single-
// store "one worker owns everything" case — produces bitwise-identical
// derivatives. Versus the flat kernels the result differs only by
// prefix reassociation (≤1e-12 relative; the vs-classic parity gates
// are KKT-certified at 1e-8).

/// Canonical tile size (rows) for the mergeable kernels. A constant —
/// NOT the tunable `Compute::block_rows` — so sharded and single-store
/// fits always agree on the decomposition.
pub(crate) const MERGE_TILE_ROWS: usize = 4096;

/// Canonical tile cuts (tie-group index boundaries) for a problem's
/// groups: [`kernels::row_tiles`] at [`MERGE_TILE_ROWS`].
pub(crate) fn merge_tiles(groups: &[TieGroup]) -> Vec<usize> {
    kernels::row_tiles(groups, MERGE_TILE_ROWS)
}

/// One risk-set power-sum triple (Σw, Σw·x, Σw·x²) — a per-group or
/// per-tile subtotal, and the mergeable carry between tiles.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub(crate) struct RiskPartials {
    pub s0: f64,
    pub s1: f64,
    pub s2: f64,
}

/// Reusable per-column scratch for the tiled merged pass: per-group
/// subtotals plus per-tile totals, sized on first use.
#[derive(Default, Debug)]
pub struct MergeScratch {
    gs: Vec<RiskPartials>,
    ts: Vec<RiskPartials>,
}

/// Phase A for one tile (groups `g_lo..g_hi`): per-group subtotals
/// accumulated from zero into `gs` (indexed `gi - g_lo`), returning the
/// tile's component-wise total in group order. `w`/`col` are slices
/// whose index 0 is global row `row0` (a shard worker passes its own
/// range; the single-store path passes the full column with `row0 = 0`).
/// Backend contract matches the flat kernels: lane sums only inside tie
/// groups of ≥ [`kernels::LANE_MIN`] samples.
#[allow(clippy::too_many_arguments)]
pub(crate) fn tile_scan_b(
    backend: KernelBackend,
    groups: &[TieGroup],
    g_lo: usize,
    g_hi: usize,
    w: &[f64],
    col: &[f64],
    row0: usize,
    need_s2: bool,
    gs: &mut [RiskPartials],
) -> RiskPartials {
    debug_assert_eq!(gs.len(), g_hi - g_lo);
    let mut total = RiskPartials::default();
    for gi in g_lo..g_hi {
        let g = &groups[gi];
        let (a, b) = (g.start - row0, g.end - row0);
        let mut part = RiskPartials::default();
        if backend == KernelBackend::Simd && b - a >= kernels::LANE_MIN {
            if need_s2 {
                let (gs0, gs1, gs2) = kernels::sum3(&w[a..b], &col[a..b]);
                part = RiskPartials { s0: gs0, s1: gs1, s2: gs2 };
            } else {
                let (gs0, gs1) = kernels::sum2(&w[a..b], &col[a..b]);
                part = RiskPartials { s0: gs0, s1: gs1, s2: 0.0 };
            }
        } else if need_s2 {
            for k in a..b {
                let wk = w[k];
                let x = col[k];
                part.s0 += wk;
                part.s1 += wk * x;
                part.s2 += wk * x * x;
            }
        } else {
            for k in a..b {
                let wk = w[k];
                part.s0 += wk;
                part.s1 += wk * col[k];
            }
        }
        gs[gi - g_lo] = part;
        total.s0 += part.s0;
        total.s1 += part.s1;
        if need_s2 {
            total.s2 += part.s2;
        }
    }
    total
}

/// Phase B for one tile: replay the running prefix as `carry` + local
/// per-group subtotals, accumulating the tile's event-group emissions
/// `(Σ ne·m1, Σ ne·(m2 − m1²))` from zero in group order.
pub(crate) fn tile_emit(
    groups: &[TieGroup],
    g_lo: usize,
    g_hi: usize,
    carry: RiskPartials,
    gs: &[RiskPartials],
    need_s2: bool,
) -> (f64, f64) {
    debug_assert_eq!(gs.len(), g_hi - g_lo);
    let mut run = carry;
    let (mut e1, mut e2) = (0.0_f64, 0.0_f64);
    for gi in g_lo..g_hi {
        let part = gs[gi - g_lo];
        run.s0 += part.s0;
        run.s1 += part.s1;
        if need_s2 {
            run.s2 += part.s2;
        }
        let g = &groups[gi];
        if g.n_events > 0 {
            let ne = g.n_events as f64;
            let m1 = run.s1 / run.s0;
            e1 += ne * m1;
            if need_s2 {
                let m2 = run.s2 / run.s0;
                e2 += ne * (m2 - m1 * m1);
            }
        }
    }
    (e1, e2)
}

/// Exclusive prefix fold of per-tile totals in tile order — the serial
/// carry chain between Phase A and Phase B. `carries[t]` is the risk-set
/// prefix entering tile `t`; component-wise f64 adds in tile order.
pub(crate) fn fold_carries(ts: &[RiskPartials], need_s2: bool) -> Vec<RiskPartials> {
    let mut carries = Vec::with_capacity(ts.len());
    let mut run = RiskPartials::default();
    for t in ts {
        carries.push(run);
        run.s0 += t.s0;
        run.s1 += t.s1;
        if need_s2 {
            run.s2 += t.s2;
        }
    }
    carries
}

/// Merged-tile d1 (and d2 when `need_d2`) over one full column: the
/// canonical tiled decomposition run serially by one caller. Bitwise
/// identical to the same tiles fanned across any number of shard
/// workers, because every float lands in a per-tile accumulator or the
/// canonical tile-order folds. `tile_cuts` comes from [`merge_tiles`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn coord_d1_d2_col_merged_b(
    backend: KernelBackend,
    groups: &[TieGroup],
    tile_cuts: &[usize],
    w: &[f64],
    col: &[f64],
    xt_delta_l: f64,
    need_d2: bool,
    scratch: &mut MergeScratch,
) -> (f64, f64) {
    let ntiles = tile_cuts.len().saturating_sub(1);
    scratch.gs.resize(groups.len(), RiskPartials::default());
    scratch.ts.clear();
    scratch.ts.reserve(ntiles);
    for t in 0..ntiles {
        let (g_lo, g_hi) = (tile_cuts[t], tile_cuts[t + 1]);
        let total = tile_scan_b(
            backend,
            groups,
            g_lo,
            g_hi,
            w,
            col,
            0,
            need_d2,
            &mut scratch.gs[g_lo..g_hi],
        );
        scratch.ts.push(total);
    }
    let carries = fold_carries(&scratch.ts, need_d2);
    let (mut d1, mut d2) = (0.0_f64, 0.0_f64);
    for t in 0..ntiles {
        let (g_lo, g_hi) = (tile_cuts[t], tile_cuts[t + 1]);
        let (e1, e2) =
            tile_emit(groups, g_lo, g_hi, carries[t], &scratch.gs[g_lo..g_hi], need_d2);
        d1 += e1;
        d2 += e2;
    }
    (d1 - xt_delta_l, d2)
}

/// Full first/second/third derivatives (Eqs. 7–9) in one O(n) pass.
pub fn coord_derivs(problem: &CoxProblem, state: &CoxState, l: usize) -> CoordDerivs {
    coord_derivs_b(problem, state, l, default_backend())
}

/// [`coord_derivs`] with an explicit kernel backend; same tolerance
/// contract as [`coord_d1_col_b`].
pub fn coord_derivs_b(
    problem: &CoxProblem,
    state: &CoxState,
    l: usize,
    backend: KernelBackend,
) -> CoordDerivs {
    let col = problem.x.col(l);
    let w = &state.w;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0_f64, 0.0_f64, 0.0_f64, 0.0_f64);
    let mut out = CoordDerivs::default();
    for g in &problem.groups {
        if backend == KernelBackend::Simd && g.end - g.start >= kernels::LANE_MIN {
            let (gs0, gs1, gs2, gs3) =
                kernels::sum4(&w[g.start..g.end], &col[g.start..g.end]);
            s0 += gs0;
            s1 += gs1;
            s2 += gs2;
            s3 += gs3;
        } else {
            for k in g.start..g.end {
                let wk = w[k];
                let x = col[k];
                let wx = wk * x;
                s0 += wk;
                s1 += wx;
                s2 += wx * x;
                s3 += wx * x * x;
            }
        }
        if g.n_events > 0 {
            let ne = g.n_events as f64;
            let m1 = s1 / s0;
            let m2 = s2 / s0;
            let m3 = s3 / s0;
            out.d1 += ne * m1;
            // Second central moment (variance form of Eq. 8).
            out.d2 += ne * (m2 - m1 * m1);
            // Third central moment (skewness form of Eq. 9).
            out.d3 += ne * (m3 + 2.0 * m1 * m1 * m1 - 3.0 * m2 * m1);
        }
    }
    out.d1 -= problem.xt_delta[l];
    out
}

/// d1 through a shared [`Workspace`]: the first evaluation at a new η
/// runs the classic fused pass; from the second evaluation at the same η
/// on, the per-group weights are built once and every further coordinate
/// costs a single division-free pass. Never slower than [`coord_d1`] —
/// the sweet spot is ℓ1-sparse CD sweeps and screening loops, where most
/// steps leave η untouched.
pub fn coord_d1_ws(problem: &CoxProblem, state: &CoxState, ws: &mut Workspace, l: usize) -> f64 {
    coord_d1_ws_b(problem, state, ws, l, default_backend())
}

/// [`coord_d1_ws`] with an explicit kernel backend threading through both
/// the cache build and the per-coordinate passes.
pub fn coord_d1_ws_b(
    problem: &CoxProblem,
    state: &CoxState,
    ws: &mut Workspace,
    l: usize,
    backend: KernelBackend,
) -> f64 {
    let v = state.version();
    if ws.is_fresh_b(state, backend) {
        return ws.coord_d1_from_cache(problem, state, l, backend);
    }
    if ws.last_seen == Some(v) {
        ws.prepare_b(problem, state, backend);
        return ws.coord_d1_from_cache(problem, state, l, backend);
    }
    ws.last_seen = Some(v);
    coord_d1_col_b(backend, &problem.groups, &state.w, problem.x.col(l), problem.xt_delta[l])
}

/// (d1, d2) through a shared [`Workspace`]; same caching discipline as
/// [`coord_d1_ws`].
pub fn coord_d1_d2_ws(
    problem: &CoxProblem,
    state: &CoxState,
    ws: &mut Workspace,
    l: usize,
) -> (f64, f64) {
    coord_d1_d2_ws_b(problem, state, ws, l, default_backend())
}

/// [`coord_d1_d2_ws`] with an explicit kernel backend.
pub fn coord_d1_d2_ws_b(
    problem: &CoxProblem,
    state: &CoxState,
    ws: &mut Workspace,
    l: usize,
    backend: KernelBackend,
) -> (f64, f64) {
    let v = state.version();
    if ws.is_fresh_b(state, backend) {
        return ws.coord_d1_d2_from_cache(problem, state, l);
    }
    if ws.last_seen == Some(v) {
        ws.prepare_b(problem, state, backend);
        return ws.coord_d1_d2_from_cache(problem, state, l);
    }
    ws.last_seen = Some(v);
    coord_d1_d2_col_b(backend, &problem.groups, &state.w, problem.x.col(l), problem.xt_delta[l])
}

/// Batched (d1\[p\], d2\[p\]) over all coordinates — the screening hot
/// path. Cache-blocked and parallel: the per-group risk-set weights are
/// computed once per η-update into the shared [`Workspace`], then the
/// per-coordinate S1/S2 accumulation fans across feature blocks on
/// `FASTSURVIVAL_THREADS` workers. Deterministic: each column's
/// accumulation order is fixed, so results are bitwise identical for
/// every thread count.
pub fn all_coord_d1_d2(
    problem: &CoxProblem,
    state: &CoxState,
    ws: &mut Workspace,
) -> (Vec<f64>, Vec<f64>) {
    // Tiny passes (beam search probes thousands of small candidates) are
    // not worth a thread spawn; results are identical either way.
    let threads = if problem.n().saturating_mul(problem.p()) < PAR_MIN_WORK {
        1
    } else {
        num_threads()
    };
    all_coord_d1_d2_with_threads(problem, state, ws, threads)
}

/// [`all_coord_d1_d2`] with an explicit worker count (benchmarks and
/// thread-count parity tests). Crate default backend, autotuned row
/// blocking.
pub fn all_coord_d1_d2_with_threads(
    problem: &CoxProblem,
    state: &CoxState,
    ws: &mut Workspace,
    threads: usize,
) -> (Vec<f64>, Vec<f64>) {
    all_coord_d1_d2_opts(
        problem,
        state,
        ws,
        threads,
        default_backend(),
        auto_block_rows(problem.n()),
    )
}

/// The fully explicit batched pass: worker count, kernel backend, and
/// row-tile size all chosen by the caller (the resolved `Compute`).
///
/// Scalar backend: one cached per-column pass per coordinate, columns
/// fanned across [`COL_BLOCK`]-sized blocks. SIMD backend: the
/// multi-column interleaved lane kernel ([`kernels::batched_d1_d2_block`])
/// over row tiles of `block_rows` samples cut at tie-group boundaries —
/// per-column results bitwise equal to the scalar backend, wall-clock
/// substantially better because the shared weight column stays cache-hot
/// and each column owns an independent accumulator chain. Blocking and
/// kernel choice depend on shape and explicit options only — never the
/// thread count — so every `(backend, block_rows)` pair is bitwise
/// thread-invariant.
pub fn all_coord_d1_d2_opts(
    problem: &CoxProblem,
    state: &CoxState,
    ws: &mut Workspace,
    threads: usize,
    backend: KernelBackend,
    block_rows: usize,
) -> (Vec<f64>, Vec<f64>) {
    let _span = crate::obs::SpanTimer::start(crate::obs::Phase::DerivativePass);
    ws.prepare_b(problem, state, backend);
    let p = problem.p();
    crate::obs::counters::kernel_calls(backend == KernelBackend::Simd, p as u64);
    let ws_ref: &Workspace = ws;
    match backend {
        KernelBackend::Scalar => {
            if threads <= 1 || p < 2 * COL_BLOCK {
                let mut d1 = vec![0.0; p];
                let mut d2 = vec![0.0; p];
                for l in 0..p {
                    let (a, b) = ws_ref.coord_d1_d2_from_cache(problem, state, l);
                    d1[l] = a;
                    d2[l] = b;
                }
                return (d1, d2);
            }
            let nblocks = (p + COL_BLOCK - 1) / COL_BLOCK;
            let blocks: Vec<usize> = (0..nblocks).collect();
            let per_block = par_map_workers(&blocks, threads, |&b| {
                let lo = b * COL_BLOCK;
                let hi = (lo + COL_BLOCK).min(p);
                (lo..hi)
                    .map(|l| ws_ref.coord_d1_d2_from_cache(problem, state, l))
                    .collect::<Vec<(f64, f64)>>()
            });
            let mut d1 = vec![0.0; p];
            let mut d2 = vec![0.0; p];
            for (b, vals) in per_block.into_iter().enumerate() {
                for (j, (a, bb)) in vals.into_iter().enumerate() {
                    d1[b * COL_BLOCK + j] = a;
                    d2[b * COL_BLOCK + j] = bb;
                }
            }
            (d1, d2)
        }
        KernelBackend::Simd => {
            let (inv_s0, gweight) = ws_ref.cache_parts();
            let tile_cuts = kernels::row_tiles(&problem.groups, block_rows);
            if threads <= 1 || p < 2 * COL_BLOCK {
                let mut d1 = vec![0.0; p];
                let mut d2 = vec![0.0; p];
                kernels::batched_d1_d2_block(
                    &problem.groups,
                    &state.w,
                    &problem.x,
                    &problem.xt_delta,
                    inv_s0,
                    gweight,
                    &tile_cuts,
                    0,
                    p,
                    &mut d1,
                    &mut d2,
                );
                return (d1, d2);
            }
            let nblocks = (p + COL_BLOCK - 1) / COL_BLOCK;
            let blocks: Vec<usize> = (0..nblocks).collect();
            let per_block = par_map_workers(&blocks, threads, |&b| {
                let lo = b * COL_BLOCK;
                let hi = (lo + COL_BLOCK).min(p);
                let mut bd1 = vec![0.0; hi - lo];
                let mut bd2 = vec![0.0; hi - lo];
                kernels::batched_d1_d2_block(
                    &problem.groups,
                    &state.w,
                    &problem.x,
                    &problem.xt_delta,
                    inv_s0,
                    gweight,
                    &tile_cuts,
                    lo,
                    hi,
                    &mut bd1,
                    &mut bd2,
                );
                (bd1, bd2)
            });
            let mut d1 = vec![0.0; p];
            let mut d2 = vec![0.0; p];
            for (b, (bd1, bd2)) in per_block.into_iter().enumerate() {
                let lo = b * COL_BLOCK;
                d1[lo..lo + bd1.len()].copy_from_slice(&bd1);
                d2[lo..lo + bd2.len()].copy_from_slice(&bd2);
            }
            (d1, d2)
        }
    }
}

/// The seed's sequential batched pass (shared S0 prefix, one division
/// per group per column, no blocking). Kept verbatim as the reference
/// kernel for `bench` speedup reporting and parity tests.
pub fn all_coord_d1_d2_seq(problem: &CoxProblem, state: &CoxState) -> (Vec<f64>, Vec<f64>) {
    let ngroups = problem.groups.len();
    let mut group_s0 = Vec::with_capacity(ngroups);
    let mut s0 = 0.0_f64;
    for g in &problem.groups {
        for k in g.start..g.end {
            s0 += state.w[k];
        }
        group_s0.push(s0);
    }

    let p = problem.p();
    let mut d1 = vec![0.0; p];
    let mut d2 = vec![0.0; p];
    for l in 0..p {
        let col = problem.x.col(l);
        let (mut s1, mut s2) = (0.0_f64, 0.0_f64);
        let (mut a1, mut a2) = (0.0_f64, 0.0_f64);
        for (gi, g) in problem.groups.iter().enumerate() {
            for k in g.start..g.end {
                let wx = state.w[k] * col[k];
                s1 += wx;
                s2 += wx * col[k];
            }
            if g.n_events > 0 {
                let ne = g.n_events as f64;
                let inv_s0 = 1.0 / group_s0[gi];
                let m1 = s1 * inv_s0;
                let m2 = s2 * inv_s0;
                a1 += ne * m1;
                a2 += ne * (m2 - m1 * m1);
            }
        }
        d1[l] = a1 - problem.xt_delta[l];
        d2[l] = a2;
    }
    (d1, d2)
}

/// Gradient of ℓ w.r.t. η (sample space), O(n). For sample k:
/// `u_k = w_k · Σ_{groups g ⪰ g(k)} (n_events(g) / S0(g)) − δ_k`,
/// the suffix sum running over groups whose risk set contains k.
pub fn eta_gradient(problem: &CoxProblem, state: &CoxState) -> Vec<f64> {
    eta_gradient_ws(problem, state, &mut Workspace::default())
}

/// [`eta_gradient`] through a shared [`Workspace`] (the suffix weights
/// A(g) come straight from the cache when fresh).
pub fn eta_gradient_ws(problem: &CoxProblem, state: &CoxState, ws: &mut Workspace) -> Vec<f64> {
    ws.prepare(problem, state);
    let n = problem.n();
    let mut u = vec![0.0; n];
    for k in 0..n {
        u[k] = state.w[k] * ws.suffix_a[problem.group_of[k]] - problem.delta[k];
    }
    u
}

/// Diagonal of the η-space Hessian, O(n):
/// `h_k = w_k·A(g(k)) − w_k²·B(g(k))` with `B(g) = Σ_{g'⪰g} ne/S0²`.
pub fn eta_hessian_diag(problem: &CoxProblem, state: &CoxState) -> Vec<f64> {
    eta_hessian_diag_ws(problem, state, &mut Workspace::default())
}

/// [`eta_hessian_diag`] through a shared [`Workspace`].
pub fn eta_hessian_diag_ws(
    problem: &CoxProblem,
    state: &CoxState,
    ws: &mut Workspace,
) -> Vec<f64> {
    ws.prepare(problem, state);
    let n = problem.n();
    let mut h = vec![0.0; n];
    for k in 0..n {
        let g = problem.group_of[k];
        let wk = state.w[k];
        h[k] = wk * ws.suffix_a[g] - wk * wk * ws.suffix_b[g];
    }
    h
}

/// Full gradient ∇_β ℓ = X^T ∇_η ℓ, O(np).
pub fn beta_gradient(problem: &CoxProblem, state: &CoxState) -> Vec<f64> {
    beta_gradient_ws(problem, state, &mut Workspace::default())
}

/// [`beta_gradient`] through a shared [`Workspace`], with the p column
/// dot products fanned across feature blocks when p is large.
pub fn beta_gradient_ws(problem: &CoxProblem, state: &CoxState, ws: &mut Workspace) -> Vec<f64> {
    let u = eta_gradient_ws(problem, state, ws);
    let p = problem.p();
    // Branch on problem shape ONLY — never on the thread count — so the
    // kernel (and its floating-point rounding) is identical for every
    // FASTSURVIVAL_THREADS setting; with one worker the fan-out below
    // degrades to a sequential loop over the same per-column dots.
    if p < 2 * COL_BLOCK || problem.n().saturating_mul(p) < PAR_MIN_WORK {
        return problem.x.tr_matvec(&u);
    }
    par_map_indices(p, |l| {
        let col = problem.x.col(l);
        col.iter().zip(u.iter()).map(|(&x, &uk)| x * uk).sum::<f64>()
    })
}

/// Full β-space Hessian for exact Newton, O(n·p²):
/// `H = Σ_i δ_i [ M(R_i)/S0_i − v(R_i) v(R_i)^T / S0_i² ]`
/// where `M(R) = Σ_{k∈R} w_k x_k x_k^T` and `v(R) = Σ_{k∈R} w_k x_k` are
/// prefix accumulations.
pub fn beta_hessian(problem: &CoxProblem, state: &CoxState) -> Matrix {
    beta_hessian_ws(problem, state, &mut Workspace::default())
}

/// [`beta_hessian`] through a shared [`Workspace`], parallel over rows
/// of the upper triangle.
///
/// Uses the same suffix-weight identity as the blocked batched pass:
/// `H = Σ_k w_k A(g(k)) x_k x_kᵀ − Σ_g (ne_g/S0_g²) v_g v_gᵀ`, so the
/// first term is a weighted Gram matrix (independent per entry — ideal
/// fan-out) and only the per-event-group prefix vectors v_g carry the
/// sequential prefix structure, materialized once. Falls back to the
/// seed's streaming kernel when the v_g buffer would exceed
/// [`HESSIAN_V_CAP`] or when running single-threaded.
pub fn beta_hessian_ws(problem: &CoxProblem, state: &CoxState, ws: &mut Workspace) -> Matrix {
    let p = problem.p();
    let n = problem.n();
    // Event groups: only groups with n_events > 0 contribute to the
    // rank-1 subtraction.
    let ev: Vec<usize> = (0..problem.groups.len())
        .filter(|&g| problem.groups[g].n_events > 0)
        .collect();
    let nev = ev.len();
    // Formulation choice depends on problem shape ONLY (never the thread
    // count): the same data yields bitwise-identical Hessians for every
    // FASTSURVIVAL_THREADS setting — with one worker the fan-outs below
    // run sequentially over the same per-entry dots.
    if p < 2 || n.saturating_mul(p) < PAR_MIN_WORK || nev.saturating_mul(p) > HESSIAN_V_CAP {
        return beta_hessian_streaming(problem, state);
    }
    ws.prepare(problem, state);
    // First-term weights c_k = w_k · A(g(k)).
    let mut c = Vec::with_capacity(n);
    for (k, &wk) in state.w.iter().enumerate() {
        c.push(wk * ws.suffix_a[problem.group_of[k]]);
    }
    // Second-term coefficients b_e = ne/S0² per event group.
    let mut bcoef = Vec::with_capacity(nev);
    for &g in &ev {
        let inv = ws.group_inv_s0[g];
        bcoef.push(problem.groups[g].n_events as f64 * inv * inv);
    }
    // v_g prefixes per column: V[j][e] = Σ_{k < end_{ev[e]}} w_k x_kj.
    let v: Vec<Vec<f64>> = par_map_indices(p, |j| {
        let col = problem.x.col(j);
        let mut out = vec![0.0_f64; nev];
        let mut acc = 0.0_f64;
        let mut e = 0usize;
        for (gi, g) in problem.groups.iter().enumerate() {
            for k in g.start..g.end {
                acc += state.w[k] * col[k];
            }
            if e < nev && ev[e] == gi {
                out[e] = acc;
                e += 1;
            }
        }
        out
    });
    // Upper-triangle rows in parallel; each entry is two clean dots.
    let rows: Vec<Vec<f64>> = par_map_indices(p, |j| {
        let colj = problem.x.col(j);
        let vj = &v[j];
        let mut row = Vec::with_capacity(p - j);
        for j2 in j..p {
            let colj2 = problem.x.col(j2);
            let mut acc = 0.0_f64;
            for ((&ck, &xa), &xb) in c.iter().zip(colj).zip(colj2) {
                acc += ck * xa * xb;
            }
            let vj2 = &v[j2];
            let mut sub = 0.0_f64;
            for ((&be, &va), &vb) in bcoef.iter().zip(vj).zip(vj2) {
                sub += be * va * vb;
            }
            row.push(acc - sub);
        }
        row
    });
    let mut h = Matrix::zeros(p, p);
    for (j, row) in rows.iter().enumerate() {
        for (off, &val) in row.iter().enumerate() {
            let j2 = j + off;
            h.set(j, j2, val);
            h.set(j2, j, val);
        }
    }
    h
}

/// The seed's streaming sequential β-Hessian kernel (prefix M and v
/// accumulated group by group).
fn beta_hessian_streaming(problem: &CoxProblem, state: &CoxState) -> Matrix {
    let p = problem.p();
    let mut h = Matrix::zeros(p, p);
    let mut m = Matrix::zeros(p, p);
    let mut v = vec![0.0_f64; p];
    let mut s0 = 0.0_f64;
    let mut xk = vec![0.0_f64; p];
    for g in &problem.groups {
        for k in g.start..g.end {
            let wk = state.w[k];
            s0 += wk;
            for (j, x) in xk.iter_mut().enumerate() {
                *x = problem.x.get(k, j);
            }
            for j in 0..p {
                let wx = wk * xk[j];
                v[j] += wx;
                // Upper triangle only; mirror at the end.
                for j2 in j..p {
                    let val = m.get(j, j2) + wx * xk[j2];
                    m.set(j, j2, val);
                }
            }
        }
        if g.n_events > 0 {
            let ne = g.n_events as f64;
            let inv = 1.0 / s0;
            let inv2 = inv * inv;
            for j in 0..p {
                for j2 in j..p {
                    let val = h.get(j, j2) + ne * (m.get(j, j2) * inv - v[j] * v[j2] * inv2);
                    h.set(j, j2, val);
                }
            }
        }
    }
    // Mirror to lower triangle.
    for j in 0..p {
        for j2 in (j + 1)..p {
            let v_ = h.get(j, j2);
            h.set(j2, j, v_);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cox::loss::loss_for_eta;
    use crate::cox::moments::{naive_coord_derivs, naive_eta_gradient};
    use crate::data::SurvivalDataset;
    use crate::linalg::Matrix;
    use crate::util::rng::Rng;

    fn random_problem(n: usize, p: usize, seed: u64, ties: bool) -> CoxProblem {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f64>> =
            (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let time: Vec<f64> = (0..n)
            .map(|_| {
                let t = rng.uniform_range(0.5, 9.5);
                if ties {
                    (t * 2.0).round() / 2.0
                } else {
                    t
                }
            })
            .collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.6)).collect();
        CoxProblem::new(&SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "r"))
    }

    #[test]
    fn matches_naive_o_n2() {
        for &ties in &[false, true] {
            for seed in 0..3 {
                let pr = random_problem(35, 4, seed, ties);
                let mut rng = Rng::new(50 + seed);
                let beta: Vec<f64> = (0..4).map(|_| rng.normal() * 0.4).collect();
                let st = CoxState::from_beta(&pr, &beta);
                for l in 0..4 {
                    let fast = coord_derivs(&pr, &st, l);
                    let naive = naive_coord_derivs(&pr, &st.eta, l);
                    assert!((fast.d1 - naive.d1).abs() < 1e-8, "d1 {} {}", fast.d1, naive.d1);
                    assert!((fast.d2 - naive.d2).abs() < 1e-8, "d2 {} {}", fast.d2, naive.d2);
                    assert!((fast.d3 - naive.d3).abs() < 1e-7, "d3 {} {}", fast.d3, naive.d3);
                }
            }
        }
    }

    #[test]
    fn d1_matches_finite_difference_of_loss() {
        let pr = random_problem(40, 3, 7, false);
        let beta = vec![0.2, -0.1, 0.3];
        let st = CoxState::from_beta(&pr, &beta);
        let h = 1e-5;
        for l in 0..3 {
            let d = coord_derivs(&pr, &st, l);
            let mut bp = beta.clone();
            bp[l] += h;
            let mut bm = beta.clone();
            bm[l] -= h;
            let lp = loss_for_eta(&pr, &pr.x.matvec(&bp));
            let lm = loss_for_eta(&pr, &pr.x.matvec(&bm));
            let fd1 = (lp - lm) / (2.0 * h);
            let l0 = loss_for_eta(&pr, &pr.x.matvec(&beta));
            let fd2 = (lp - 2.0 * l0 + lm) / (h * h);
            assert!((d.d1 - fd1).abs() < 1e-5, "fd d1: {} vs {}", d.d1, fd1);
            assert!((d.d2 - fd2).abs() < 1e-3, "fd d2: {} vs {}", d.d2, fd2);
        }
    }

    #[test]
    fn d3_matches_finite_difference_of_d2() {
        let pr = random_problem(30, 2, 17, false);
        let beta = vec![0.1, -0.2];
        let h = 1e-5;
        for l in 0..2 {
            let d0 = coord_derivs(&pr, &CoxState::from_beta(&pr, &beta), l);
            let mut bp = beta.clone();
            bp[l] += h;
            let dp = coord_derivs(&pr, &CoxState::from_beta(&pr, &bp), l);
            let fd3 = (dp.d2 - d0.d2) / h;
            assert!((d0.d3 - fd3).abs() < 1e-3, "fd d3: {} vs {}", d0.d3, fd3);
        }
    }

    #[test]
    fn d2_nonnegative_always() {
        // Variance interpretation ⇒ d2 ≥ 0 (Theorem 3.4 lower bound).
        for seed in 0..6 {
            let pr = random_problem(25, 3, seed, seed % 2 == 0);
            let mut rng = Rng::new(seed + 200);
            let beta: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
            let st = CoxState::from_beta(&pr, &beta);
            for l in 0..3 {
                let d = coord_derivs(&pr, &st, l);
                assert!(d.d2 >= -1e-10, "d2={}", d.d2);
            }
        }
    }

    #[test]
    fn batched_matches_single() {
        let pr = random_problem(30, 5, 23, true);
        let st = CoxState::from_beta(&pr, &[0.1, 0.2, -0.3, 0.0, 0.5]);
        let mut ws = Workspace::default();
        let (d1s, d2s) = all_coord_d1_d2(&pr, &st, &mut ws);
        for l in 0..5 {
            let (d1, d2) = coord_d1_d2(&pr, &st, l);
            assert!((d1s[l] - d1).abs() < 1e-10);
            assert!((d2s[l] - d2).abs() < 1e-10);
        }
    }

    #[test]
    fn blocked_matches_seq_across_thread_counts() {
        for &ties in &[false, true] {
            let pr = random_problem(120, 37, 29, ties);
            let mut rng = Rng::new(91);
            let beta: Vec<f64> = (0..37).map(|_| rng.normal() * 0.3).collect();
            let st = CoxState::from_beta(&pr, &beta);
            let (r1, r2) = all_coord_d1_d2_seq(&pr, &st);
            for &threads in &[1usize, 2, 4] {
                let mut ws = Workspace::default();
                let (d1, d2) = all_coord_d1_d2_with_threads(&pr, &st, &mut ws, threads);
                for l in 0..pr.p() {
                    assert!(
                        (d1[l] - r1[l]).abs() < 1e-10,
                        "threads={threads} l={l}: {} vs {}",
                        d1[l],
                        r1[l]
                    );
                    assert!((d2[l] - r2[l]).abs() < 1e-10);
                }
            }
        }
    }

    #[test]
    fn cached_coord_passes_match_classic() {
        let pr = random_problem(80, 6, 57, true);
        let mut st = CoxState::from_beta(&pr, &[0.1, -0.2, 0.3, 0.0, 0.2, -0.1]);
        let mut ws = Workspace::default();
        // First eval at this η: classic path. Second and later: cache
        // built and used. After a state mutation: classic again.
        for round in 0..3 {
            for l in 0..pr.p() {
                let want = coord_d1(&pr, &st, l);
                let got = coord_d1_ws(&pr, &st, &mut ws, l);
                assert!((got - want).abs() < 1e-10, "round {round} l={l}: {got} vs {want}");
                let (w1, w2) = coord_d1_d2(&pr, &st, l);
                let (g1, g2) = coord_d1_d2_ws(&pr, &st, &mut ws, l);
                assert!((g1 - w1).abs() < 1e-10);
                assert!((g2 - w2).abs() < 1e-10);
            }
            st.update_coord(&pr, round % pr.p(), 0.05);
        }
    }

    #[test]
    fn scalar_and_simd_columns_agree() {
        use crate::util::compute::KernelBackend;
        // Untied data: every tie group is a singleton, the SIMD arm takes
        // the scalar path group by group — bitwise equality. Tied data:
        // lane reassociation inside big groups — ≤1e-12 relative.
        for &ties in &[false, true] {
            let pr = random_problem(160, 7, 71, ties);
            let mut rng = Rng::new(72);
            let beta: Vec<f64> = (0..7).map(|_| rng.normal() * 0.3).collect();
            let st = CoxState::from_beta(&pr, &beta);
            for l in 0..pr.p() {
                let col = pr.x.col(l);
                let xd = pr.xt_delta[l];
                let ds = coord_d1_col_b(KernelBackend::Scalar, &pr.groups, &st.w, col, xd);
                let dv = coord_d1_col_b(KernelBackend::Simd, &pr.groups, &st.w, col, xd);
                let (s1, s2) = coord_d1_d2_col_b(KernelBackend::Scalar, &pr.groups, &st.w, col, xd);
                let (v1, v2) = coord_d1_d2_col_b(KernelBackend::Simd, &pr.groups, &st.w, col, xd);
                let cs = coord_derivs_b(&pr, &st, l, KernelBackend::Scalar);
                let cv = coord_derivs_b(&pr, &st, l, KernelBackend::Simd);
                if !ties {
                    assert_eq!(ds.to_bits(), dv.to_bits(), "l={l} d1 not bitwise");
                    assert_eq!(s1.to_bits(), v1.to_bits());
                    assert_eq!(s2.to_bits(), v2.to_bits());
                    assert_eq!(cs.d1.to_bits(), cv.d1.to_bits());
                    assert_eq!(cs.d2.to_bits(), cv.d2.to_bits());
                    assert_eq!(cs.d3.to_bits(), cv.d3.to_bits());
                } else {
                    let tol = |a: f64| 1e-12 * a.abs().max(1.0);
                    assert!((ds - dv).abs() <= tol(ds), "l={l}: {ds} vs {dv}");
                    assert!((s1 - v1).abs() <= tol(s1));
                    assert!((s2 - v2).abs() <= tol(s2));
                    assert!((cs.d1 - cv.d1).abs() <= tol(cs.d1));
                    assert!((cs.d2 - cv.d2).abs() <= tol(cs.d2));
                    assert!((cs.d3 - cv.d3).abs() <= tol(cs.d3));
                }
            }
        }
    }

    #[test]
    fn batched_backends_bitwise_across_threads_and_blocks() {
        use crate::util::compute::KernelBackend;
        // Within a backend, results are bitwise invariant to thread count
        // and row-tile size (blocking lands on group boundaries and the
        // per-column op order never changes). Across backends, untied data
        // is bitwise too (identical caches, identical per-column order);
        // with ties the lane-summed cache differs, so ≤1e-12 relative.
        for &ties in &[false, true] {
            let pr = random_problem(300, 23, 83, ties);
            let mut rng = Rng::new(84);
            let beta: Vec<f64> = (0..23).map(|_| rng.normal() * 0.3).collect();
            let st = CoxState::from_beta(&pr, &beta);
            let mut ws = Workspace::default();
            let (r1, r2) =
                all_coord_d1_d2_opts(&pr, &st, &mut ws, 1, KernelBackend::Scalar, 64);
            for &threads in &[1usize, 2, 4] {
                for &block_rows in &[64usize, 100, 4096] {
                    for &backend in &[KernelBackend::Scalar, KernelBackend::Simd] {
                        let mut ws2 = Workspace::default();
                        let (d1, d2) = all_coord_d1_d2_opts(
                            &pr, &st, &mut ws2, threads, backend, block_rows,
                        );
                        let bitwise = !ties || backend == KernelBackend::Scalar;
                        for l in 0..pr.p() {
                            if bitwise {
                                assert_eq!(
                                    d1[l].to_bits(),
                                    r1[l].to_bits(),
                                    "ties={ties} threads={threads} block={block_rows} l={l}"
                                );
                                assert_eq!(d2[l].to_bits(), r2[l].to_bits());
                            } else {
                                let tol = |a: f64| 1e-12 * a.abs().max(1.0);
                                assert!(
                                    (d1[l] - r1[l]).abs() <= tol(r1[l]),
                                    "threads={threads} block={block_rows} l={l}: {} vs {}",
                                    d1[l],
                                    r1[l]
                                );
                                assert!((d2[l] - r2[l]).abs() <= tol(r2[l]));
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn merged_tiles_match_flat_kernels() {
        use crate::util::compute::KernelBackend;
        // The canonical tiled decomposition reassociates the running
        // prefix at tile boundaries only — ≤1e-12 of the flat kernels,
        // for both backends, tied and untied data, d1-only and d1+d2.
        for &ties in &[false, true] {
            let pr = random_problem(700, 5, 97, ties);
            let mut rng = Rng::new(98);
            let beta: Vec<f64> = (0..5).map(|_| rng.normal() * 0.3).collect();
            let st = CoxState::from_beta(&pr, &beta);
            // Small tile size so the test exercises several tiles.
            let cuts = kernels::row_tiles(&pr.groups, 64);
            assert!(cuts.len() > 3, "want multiple tiles, got {cuts:?}");
            let mut scratch = MergeScratch::default();
            for &backend in &[KernelBackend::Scalar, KernelBackend::Simd] {
                for l in 0..pr.p() {
                    let col = pr.x.col(l);
                    let xd = pr.xt_delta[l];
                    let flat1 = coord_d1_col_b(backend, &pr.groups, &st.w, col, xd);
                    let (m1, m2_zero) = coord_d1_d2_col_merged_b(
                        backend, &pr.groups, &cuts, &st.w, col, xd, false, &mut scratch,
                    );
                    let tol = |a: f64| 1e-12 * a.abs().max(1.0);
                    assert!((m1 - flat1).abs() <= tol(flat1), "l={l}: {m1} vs {flat1}");
                    assert_eq!(m2_zero, 0.0);
                    let (f1, f2) = coord_d1_d2_col_b(backend, &pr.groups, &st.w, col, xd);
                    let (g1, g2) = coord_d1_d2_col_merged_b(
                        backend, &pr.groups, &cuts, &st.w, col, xd, true, &mut scratch,
                    );
                    assert!((g1 - f1).abs() <= tol(f1), "l={l}: {g1} vs {f1}");
                    assert!((g2 - f2).abs() <= tol(f2), "l={l}: {g2} vs {f2}");
                }
            }
        }
    }

    #[test]
    fn merged_tiles_are_partition_invariant() {
        use crate::util::compute::KernelBackend;
        // Splitting the SAME canonical tiles across simulated workers and
        // folding carries/emissions in tile order must be bitwise equal
        // to the serial merged pass — the property the sharded engine
        // stands on.
        let pr = random_problem(500, 3, 101, true);
        let st = CoxState::from_beta(&pr, &[0.2, -0.1, 0.3]);
        let cuts = kernels::row_tiles(&pr.groups, 48);
        let ntiles = cuts.len() - 1;
        assert!(ntiles >= 4);
        let mut scratch = MergeScratch::default();
        for l in 0..pr.p() {
            let col = pr.x.col(l);
            let xd = pr.xt_delta[l];
            let serial = coord_d1_d2_col_merged_b(
                KernelBackend::Simd, &pr.groups, &cuts, &st.w, col, xd, true, &mut scratch,
            );
            for workers in [1usize, 2, 3, 4] {
                // Simulated fan-out: each "worker" owns a contiguous tile
                // range and sees only its own row slice.
                let mut gs = vec![RiskPartials::default(); pr.groups.len()];
                let mut ts = vec![RiskPartials::default(); ntiles];
                let per = ntiles.div_ceil(workers);
                for wk in 0..workers {
                    let (t_lo, t_hi) = (wk * per, ((wk + 1) * per).min(ntiles));
                    for t in t_lo..t_hi {
                        let (g_lo, g_hi) = (cuts[t], cuts[t + 1]);
                        let row0 = pr.groups[g_lo].start;
                        let row1 = pr.groups[g_hi - 1].end;
                        ts[t] = tile_scan_b(
                            KernelBackend::Simd,
                            &pr.groups,
                            g_lo,
                            g_hi,
                            &st.w[row0..row1],
                            &col[row0..row1],
                            row0,
                            true,
                            &mut gs[g_lo..g_hi],
                        );
                    }
                }
                let carries = fold_carries(&ts, true);
                let (mut d1, mut d2) = (0.0_f64, 0.0_f64);
                for t in 0..ntiles {
                    let (g_lo, g_hi) = (cuts[t], cuts[t + 1]);
                    let (e1, e2) =
                        tile_emit(&pr.groups, g_lo, g_hi, carries[t], &gs[g_lo..g_hi], true);
                    d1 += e1;
                    d2 += e2;
                }
                d1 -= xd;
                assert_eq!(d1.to_bits(), serial.0.to_bits(), "workers={workers} l={l}");
                assert_eq!(d2.to_bits(), serial.1.to_bits(), "workers={workers} l={l}");
            }
        }
    }

    #[test]
    fn workspace_backend_switch_rebuilds_cache() {
        use crate::util::compute::KernelBackend;
        // A cache built by one backend must not be served to the other at
        // the same η: with ties the prefixes differ slightly, and both
        // backends must answer exactly as a fresh workspace would.
        let pr = random_problem(90, 5, 87, true);
        let st = CoxState::from_beta(&pr, &[0.2, -0.1, 0.3, 0.0, 0.1]);
        let mut ws = Workspace::default();
        for &backend in
            &[KernelBackend::Simd, KernelBackend::Scalar, KernelBackend::Simd]
        {
            ws.prepare_b(&pr, &st, backend);
            for l in 0..pr.p() {
                let want = {
                    let mut fresh = Workspace::default();
                    fresh.prepare_b(&pr, &st, backend);
                    fresh.coord_d1_d2_from_cache(&pr, &st, l)
                };
                let got = ws.coord_d1_d2_from_cache(&pr, &st, l);
                assert_eq!(got.0.to_bits(), want.0.to_bits(), "backend={backend:?} l={l}");
                assert_eq!(got.1.to_bits(), want.1.to_bits());
            }
        }
    }

    #[test]
    fn workspace_survives_interleaved_states() {
        // A single workspace serving two states alternately must never
        // return weights cached for the other state.
        let pr = random_problem(60, 4, 61, false);
        let sa = CoxState::from_beta(&pr, &[0.2, -0.1, 0.0, 0.3]);
        let sb = CoxState::from_beta(&pr, &[-0.3, 0.4, 0.1, 0.0]);
        let mut ws = Workspace::default();
        for _ in 0..3 {
            for st in [&sa, &sb] {
                for l in 0..pr.p() {
                    let want = coord_d1(&pr, st, l);
                    let got = coord_d1_ws(&pr, st, &mut ws, l);
                    assert!((got - want).abs() < 1e-10, "{got} vs {want}");
                }
            }
        }
    }

    #[test]
    fn beta_hessian_blocked_matches_streaming() {
        // n·p must clear PAR_MIN_WORK or beta_hessian_ws falls back to
        // streaming and the comparison is vacuous.
        let (n, p) = (2048, 16);
        assert!(n * p >= super::PAR_MIN_WORK);
        let pr = random_problem(n, p, 63, true);
        let mut rng = Rng::new(64);
        let beta: Vec<f64> = (0..p).map(|_| rng.normal() * 0.3).collect();
        let st = CoxState::from_beta(&pr, &beta);
        let hs = beta_hessian_streaming(&pr, &st);
        let mut ws = Workspace::default();
        let hb = beta_hessian_ws(&pr, &st, &mut ws);
        for a in 0..p {
            for b in 0..p {
                let scale = hs.get(a, b).abs() + 1.0;
                assert!(
                    (hs.get(a, b) - hb.get(a, b)).abs() < 1e-7 * scale,
                    "H[{a}{b}]: {} vs {}",
                    hs.get(a, b),
                    hb.get(a, b)
                );
            }
        }
    }

    #[test]
    fn eta_gradient_matches_naive_and_chain_rule() {
        let pr = random_problem(25, 3, 31, true);
        let st = CoxState::from_beta(&pr, &[0.4, -0.2, 0.1]);
        let u = eta_gradient(&pr, &st);
        let naive = naive_eta_gradient(&pr, &st.eta);
        for k in 0..pr.n() {
            assert!((u[k] - naive[k]).abs() < 1e-9, "k={k}: {} vs {}", u[k], naive[k]);
        }
        // β gradient via X^T u must equal per-coordinate d1.
        let g = beta_gradient(&pr, &st);
        for l in 0..3 {
            let d1 = coord_d1(&pr, &st, l);
            assert!((g[l] - d1).abs() < 1e-8, "{} vs {}", g[l], d1);
        }
    }

    #[test]
    fn hessian_diag_matches_coord_d2_for_unit_columns() {
        // For the η-space Hessian, e_k^T ∇²η ℓ e_k equals the coordinate
        // second derivative when X = I.
        let n = 12;
        let mut rng = Rng::new(37);
        let cols: Vec<Vec<f64>> = (0..n)
            .map(|j| (0..n).map(|i| if i == j { 1.0 } else { 0.0 }).collect())
            .collect();
        let time: Vec<f64> = (0..n).map(|_| rng.uniform_range(1.0, 9.0)).collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.7)).collect();
        let ds = SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "i");
        let pr = CoxProblem::new(&ds);
        let beta: Vec<f64> = (0..n).map(|_| rng.normal() * 0.3).collect();
        let st = CoxState::from_beta(&pr, &beta);
        let diag = eta_hessian_diag(&pr, &st);
        for l in 0..n {
            // Column l indicates *original* sample l; that sample sits at
            // sorted position `pos`, where the η-space diagonal lives.
            let pos = pr.order.iter().position(|&o| o == l).unwrap();
            let (_, d2) = coord_d1_d2(&pr, &st, l);
            assert!((diag[pos] - d2).abs() < 1e-9, "l={l}: {} vs {}", diag[pos], d2);
        }
    }

    #[test]
    fn beta_hessian_diagonal_matches_coord_d2() {
        let pr = random_problem(30, 4, 41, false);
        let st = CoxState::from_beta(&pr, &[0.1, -0.4, 0.2, 0.0]);
        let h = beta_hessian(&pr, &st);
        for l in 0..4 {
            let (_, d2) = coord_d1_d2(&pr, &st, l);
            assert!((h.get(l, l) - d2).abs() < 1e-8, "{} vs {}", h.get(l, l), d2);
        }
        // Symmetry.
        for a in 0..4 {
            for b in 0..4 {
                assert!((h.get(a, b) - h.get(b, a)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn beta_hessian_matches_finite_difference_gradient() {
        let pr = random_problem(20, 3, 43, false);
        let beta = vec![0.2, 0.1, -0.3];
        let st = CoxState::from_beta(&pr, &beta);
        let h = beta_hessian(&pr, &st);
        let eps = 1e-5;
        for j in 0..3 {
            let mut bp = beta.clone();
            bp[j] += eps;
            let gp = beta_gradient(&pr, &CoxState::from_beta(&pr, &bp));
            let mut bm = beta.clone();
            bm[j] -= eps;
            let gm = beta_gradient(&pr, &CoxState::from_beta(&pr, &bm));
            for i in 0..3 {
                let fd = (gp[i] - gm[i]) / (2.0 * eps);
                assert!((h.get(i, j) - fd).abs() < 1e-4, "H[{i}{j}] {} vs {}", h.get(i, j), fd);
            }
        }
    }
}
