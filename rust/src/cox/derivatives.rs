//! Exact O(n) partial derivatives (Theorem 3.1 / Corollary 3.3) plus the
//! η-space quantities the Newton baselines need.
//!
//! Key observation: with samples sorted by descending time, every risk
//! set is a prefix, so the weighted power sums
//! `S_r(i) = Σ_{k∈R_i} w_k x_{kl}^r` for r = 0..3 are running prefix sums.
//! All events within a tie group share one risk set, so each group
//! contributes its moment expression once, scaled by its event count.

use super::problem::CoxProblem;
use super::state::CoxState;
use crate::linalg::Matrix;

/// First/second/third partial derivatives at one coordinate.
#[derive(Clone, Copy, Debug, Default)]
pub struct CoordDerivs {
    pub d1: f64,
    pub d2: f64,
    pub d3: f64,
}

/// Reusable buffers for batched (all-coordinate) passes.
#[derive(Default, Debug)]
pub struct Workspace {
    /// Per-group event count ÷ S0 prefix (risk-set weights), reused by
    /// the batched first/second-derivative pass.
    group_weight: Vec<f64>,
    /// Per-group prefix S0.
    group_s0: Vec<f64>,
}

/// d1 only (Eq. 7). One fused pass; the cheapest quantity the quadratic
/// surrogate needs per coordinate update.
pub fn coord_d1(problem: &CoxProblem, state: &CoxState, l: usize) -> f64 {
    let col = problem.x.col(l);
    let w = &state.w;
    let (mut s0, mut s1) = (0.0_f64, 0.0_f64);
    let mut d1 = 0.0_f64;
    for g in &problem.groups {
        for k in g.start..g.end {
            let wk = w[k];
            s0 += wk;
            s1 += wk * col[k];
        }
        if g.n_events > 0 {
            d1 += g.n_events as f64 * (s1 / s0);
        }
    }
    d1 - problem.xt_delta[l]
}

/// d1 and d2 (Eqs. 7–8). Used by the cubic surrogate and by screening.
pub fn coord_d1_d2(problem: &CoxProblem, state: &CoxState, l: usize) -> (f64, f64) {
    let col = problem.x.col(l);
    let w = &state.w;
    let (mut s0, mut s1, mut s2) = (0.0_f64, 0.0_f64, 0.0_f64);
    let (mut d1, mut d2) = (0.0_f64, 0.0_f64);
    for g in &problem.groups {
        for k in g.start..g.end {
            let wk = w[k];
            let x = col[k];
            s0 += wk;
            s1 += wk * x;
            s2 += wk * x * x;
        }
        if g.n_events > 0 {
            let ne = g.n_events as f64;
            let m1 = s1 / s0;
            let m2 = s2 / s0;
            d1 += ne * m1;
            d2 += ne * (m2 - m1 * m1);
        }
    }
    (d1 - problem.xt_delta[l], d2)
}

/// Full first/second/third derivatives (Eqs. 7–9) in one O(n) pass.
pub fn coord_derivs(problem: &CoxProblem, state: &CoxState, l: usize) -> CoordDerivs {
    let col = problem.x.col(l);
    let w = &state.w;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0_f64, 0.0_f64, 0.0_f64, 0.0_f64);
    let mut out = CoordDerivs::default();
    for g in &problem.groups {
        for k in g.start..g.end {
            let wk = w[k];
            let x = col[k];
            let wx = wk * x;
            s0 += wk;
            s1 += wx;
            s2 += wx * x;
            s3 += wx * x * x;
        }
        if g.n_events > 0 {
            let ne = g.n_events as f64;
            let m1 = s1 / s0;
            let m2 = s2 / s0;
            let m3 = s3 / s0;
            out.d1 += ne * m1;
            // Second central moment (variance form of Eq. 8).
            out.d2 += ne * (m2 - m1 * m1);
            // Third central moment (skewness form of Eq. 9).
            out.d3 += ne * (m3 + 2.0 * m1 * m1 * m1 - 3.0 * m2 * m1);
        }
    }
    out.d1 -= problem.xt_delta[l];
    out
}

/// Batched (d1\[p\], d2\[p\]) over all coordinates — the beam-search screening
/// hot path. Shares the per-group S0 prefix across all columns, so the
/// total cost is O(np) with a single pass per column over contiguous
/// column-major storage.
pub fn all_coord_d1_d2(
    problem: &CoxProblem,
    state: &CoxState,
    ws: &mut Workspace,
) -> (Vec<f64>, Vec<f64>) {
    let ngroups = problem.groups.len();
    ws.group_s0.clear();
    ws.group_s0.reserve(ngroups);
    ws.group_weight.clear();
    ws.group_weight.reserve(ngroups);
    let mut s0 = 0.0_f64;
    for g in &problem.groups {
        for k in g.start..g.end {
            s0 += state.w[k];
        }
        ws.group_s0.push(s0);
        ws.group_weight.push(g.n_events as f64 / s0);
    }

    let p = problem.p();
    let mut d1 = vec![0.0; p];
    let mut d2 = vec![0.0; p];
    for l in 0..p {
        let col = problem.x.col(l);
        let (mut s1, mut s2) = (0.0_f64, 0.0_f64);
        let (mut a1, mut a2) = (0.0_f64, 0.0_f64);
        for (gi, g) in problem.groups.iter().enumerate() {
            for k in g.start..g.end {
                let wx = state.w[k] * col[k];
                s1 += wx;
                s2 += wx * col[k];
            }
            if g.n_events > 0 {
                let ne = g.n_events as f64;
                let inv_s0 = 1.0 / ws.group_s0[gi];
                let m1 = s1 * inv_s0;
                let m2 = s2 * inv_s0;
                a1 += ne * m1;
                a2 += ne * (m2 - m1 * m1);
            }
        }
        d1[l] = a1 - problem.xt_delta[l];
        d2[l] = a2;
    }
    (d1, d2)
}

/// Gradient of ℓ w.r.t. η (sample space), O(n). For sample k:
/// `u_k = w_k · Σ_{groups g ⪰ g(k)} (n_events(g) / S0(g)) − δ_k`,
/// the suffix sum running over groups whose risk set contains k.
pub fn eta_gradient(problem: &CoxProblem, state: &CoxState) -> Vec<f64> {
    let n = problem.n();
    let ngroups = problem.groups.len();
    // Prefix S0 per group.
    let mut s0 = vec![0.0_f64; ngroups];
    let mut acc = 0.0;
    for (gi, g) in problem.groups.iter().enumerate() {
        for k in g.start..g.end {
            acc += state.w[k];
        }
        s0[gi] = acc;
    }
    // Suffix sums A(g) = Σ_{g' >= g} ne / S0.
    let mut a = vec![0.0_f64; ngroups];
    let mut suffix = 0.0;
    for gi in (0..ngroups).rev() {
        suffix += problem.groups[gi].n_events as f64 / s0[gi];
        a[gi] = suffix;
    }
    let mut u = vec![0.0; n];
    for k in 0..n {
        u[k] = state.w[k] * a[problem.group_of[k]] - problem.delta[k];
    }
    u
}

/// Diagonal of the η-space Hessian, O(n):
/// `h_k = w_k·A(g(k)) − w_k²·B(g(k))` with `B(g) = Σ_{g'⪰g} ne/S0²`.
pub fn eta_hessian_diag(problem: &CoxProblem, state: &CoxState) -> Vec<f64> {
    let n = problem.n();
    let ngroups = problem.groups.len();
    let mut s0 = vec![0.0_f64; ngroups];
    let mut acc = 0.0;
    for (gi, g) in problem.groups.iter().enumerate() {
        for k in g.start..g.end {
            acc += state.w[k];
        }
        s0[gi] = acc;
    }
    let (mut a, mut b) = (vec![0.0_f64; ngroups], vec![0.0_f64; ngroups]);
    let (mut sa, mut sb) = (0.0, 0.0);
    for gi in (0..ngroups).rev() {
        let ne = problem.groups[gi].n_events as f64;
        sa += ne / s0[gi];
        sb += ne / (s0[gi] * s0[gi]);
        a[gi] = sa;
        b[gi] = sb;
    }
    let mut h = vec![0.0; n];
    for k in 0..n {
        let g = problem.group_of[k];
        h[k] = state.w[k] * a[g] - state.w[k] * state.w[k] * b[g];
    }
    h
}

/// Full gradient ∇_β ℓ = X^T ∇_η ℓ, O(np).
pub fn beta_gradient(problem: &CoxProblem, state: &CoxState) -> Vec<f64> {
    let u = eta_gradient(problem, state);
    problem.x.tr_matvec(&u)
}

/// Full β-space Hessian for exact Newton, O(n·p²):
/// `H = Σ_i δ_i [ M(R_i)/S0_i − v(R_i) v(R_i)^T / S0_i² ]`
/// where `M(R) = Σ_{k∈R} w_k x_k x_k^T` and `v(R) = Σ_{k∈R} w_k x_k` are
/// prefix accumulations.
pub fn beta_hessian(problem: &CoxProblem, state: &CoxState) -> Matrix {
    let p = problem.p();
    let mut h = Matrix::zeros(p, p);
    let mut m = Matrix::zeros(p, p);
    let mut v = vec![0.0_f64; p];
    let mut s0 = 0.0_f64;
    let mut xk = vec![0.0_f64; p];
    for g in &problem.groups {
        for k in g.start..g.end {
            let wk = state.w[k];
            s0 += wk;
            for (j, x) in xk.iter_mut().enumerate() {
                *x = problem.x.get(k, j);
            }
            for j in 0..p {
                let wx = wk * xk[j];
                v[j] += wx;
                // Upper triangle only; mirror at the end.
                for j2 in j..p {
                    let val = m.get(j, j2) + wx * xk[j2];
                    m.set(j, j2, val);
                }
            }
        }
        if g.n_events > 0 {
            let ne = g.n_events as f64;
            let inv = 1.0 / s0;
            let inv2 = inv * inv;
            for j in 0..p {
                for j2 in j..p {
                    let val = h.get(j, j2) + ne * (m.get(j, j2) * inv - v[j] * v[j2] * inv2);
                    h.set(j, j2, val);
                }
            }
        }
    }
    // Mirror to lower triangle.
    for j in 0..p {
        for j2 in (j + 1)..p {
            let v_ = h.get(j, j2);
            h.set(j2, j, v_);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cox::loss::loss_for_eta;
    use crate::cox::moments::{naive_coord_derivs, naive_eta_gradient};
    use crate::data::SurvivalDataset;
    use crate::linalg::Matrix;
    use crate::util::rng::Rng;

    fn random_problem(n: usize, p: usize, seed: u64, ties: bool) -> CoxProblem {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f64>> =
            (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let time: Vec<f64> = (0..n)
            .map(|_| {
                let t = rng.uniform_range(0.5, 9.5);
                if ties {
                    (t * 2.0).round() / 2.0
                } else {
                    t
                }
            })
            .collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.6)).collect();
        CoxProblem::new(&SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "r"))
    }

    #[test]
    fn matches_naive_o_n2() {
        for &ties in &[false, true] {
            for seed in 0..3 {
                let pr = random_problem(35, 4, seed, ties);
                let mut rng = Rng::new(50 + seed);
                let beta: Vec<f64> = (0..4).map(|_| rng.normal() * 0.4).collect();
                let st = CoxState::from_beta(&pr, &beta);
                for l in 0..4 {
                    let fast = coord_derivs(&pr, &st, l);
                    let naive = naive_coord_derivs(&pr, &st.eta, l);
                    assert!((fast.d1 - naive.d1).abs() < 1e-8, "d1 {} {}", fast.d1, naive.d1);
                    assert!((fast.d2 - naive.d2).abs() < 1e-8, "d2 {} {}", fast.d2, naive.d2);
                    assert!((fast.d3 - naive.d3).abs() < 1e-7, "d3 {} {}", fast.d3, naive.d3);
                }
            }
        }
    }

    #[test]
    fn d1_matches_finite_difference_of_loss() {
        let pr = random_problem(40, 3, 7, false);
        let beta = vec![0.2, -0.1, 0.3];
        let st = CoxState::from_beta(&pr, &beta);
        let h = 1e-5;
        for l in 0..3 {
            let d = coord_derivs(&pr, &st, l);
            let mut bp = beta.clone();
            bp[l] += h;
            let mut bm = beta.clone();
            bm[l] -= h;
            let lp = loss_for_eta(&pr, &pr.x.matvec(&bp));
            let lm = loss_for_eta(&pr, &pr.x.matvec(&bm));
            let fd1 = (lp - lm) / (2.0 * h);
            let l0 = loss_for_eta(&pr, &pr.x.matvec(&beta));
            let fd2 = (lp - 2.0 * l0 + lm) / (h * h);
            assert!((d.d1 - fd1).abs() < 1e-5, "fd d1: {} vs {}", d.d1, fd1);
            assert!((d.d2 - fd2).abs() < 1e-3, "fd d2: {} vs {}", d.d2, fd2);
        }
    }

    #[test]
    fn d3_matches_finite_difference_of_d2() {
        let pr = random_problem(30, 2, 17, false);
        let beta = vec![0.1, -0.2];
        let h = 1e-5;
        for l in 0..2 {
            let d0 = coord_derivs(&pr, &CoxState::from_beta(&pr, &beta), l);
            let mut bp = beta.clone();
            bp[l] += h;
            let dp = coord_derivs(&pr, &CoxState::from_beta(&pr, &bp), l);
            let fd3 = (dp.d2 - d0.d2) / h;
            assert!((d0.d3 - fd3).abs() < 1e-3, "fd d3: {} vs {}", d0.d3, fd3);
        }
    }

    #[test]
    fn d2_nonnegative_always() {
        // Variance interpretation ⇒ d2 ≥ 0 (Theorem 3.4 lower bound).
        for seed in 0..6 {
            let pr = random_problem(25, 3, seed, seed % 2 == 0);
            let mut rng = Rng::new(seed + 200);
            let beta: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
            let st = CoxState::from_beta(&pr, &beta);
            for l in 0..3 {
                let d = coord_derivs(&pr, &st, l);
                assert!(d.d2 >= -1e-10, "d2={}", d.d2);
            }
        }
    }

    #[test]
    fn batched_matches_single() {
        let pr = random_problem(30, 5, 23, true);
        let st = CoxState::from_beta(&pr, &[0.1, 0.2, -0.3, 0.0, 0.5]);
        let mut ws = Workspace::default();
        let (d1s, d2s) = all_coord_d1_d2(&pr, &st, &mut ws);
        for l in 0..5 {
            let (d1, d2) = coord_d1_d2(&pr, &st, l);
            assert!((d1s[l] - d1).abs() < 1e-10);
            assert!((d2s[l] - d2).abs() < 1e-10);
        }
    }

    #[test]
    fn eta_gradient_matches_naive_and_chain_rule() {
        let pr = random_problem(25, 3, 31, true);
        let st = CoxState::from_beta(&pr, &[0.4, -0.2, 0.1]);
        let u = eta_gradient(&pr, &st);
        let naive = naive_eta_gradient(&pr, &st.eta);
        for k in 0..pr.n() {
            assert!((u[k] - naive[k]).abs() < 1e-9, "k={k}: {} vs {}", u[k], naive[k]);
        }
        // β gradient via X^T u must equal per-coordinate d1.
        let g = beta_gradient(&pr, &st);
        for l in 0..3 {
            let d1 = coord_d1(&pr, &st, l);
            assert!((g[l] - d1).abs() < 1e-8, "{} vs {}", g[l], d1);
        }
    }

    #[test]
    fn hessian_diag_matches_coord_d2_for_unit_columns() {
        // For the η-space Hessian, e_k^T ∇²η ℓ e_k equals the coordinate
        // second derivative when X = I.
        let n = 12;
        let mut rng = Rng::new(37);
        let cols: Vec<Vec<f64>> = (0..n)
            .map(|j| (0..n).map(|i| if i == j { 1.0 } else { 0.0 }).collect())
            .collect();
        let time: Vec<f64> = (0..n).map(|_| rng.uniform_range(1.0, 9.0)).collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.7)).collect();
        let ds = SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "i");
        let pr = CoxProblem::new(&ds);
        let beta: Vec<f64> = (0..n).map(|_| rng.normal() * 0.3).collect();
        let st = CoxState::from_beta(&pr, &beta);
        let diag = eta_hessian_diag(&pr, &st);
        for l in 0..n {
            // Column l indicates *original* sample l; that sample sits at
            // sorted position `pos`, where the η-space diagonal lives.
            let pos = pr.order.iter().position(|&o| o == l).unwrap();
            let (_, d2) = coord_d1_d2(&pr, &st, l);
            assert!((diag[pos] - d2).abs() < 1e-9, "l={l}: {} vs {}", diag[pos], d2);
        }
    }

    #[test]
    fn beta_hessian_diagonal_matches_coord_d2() {
        let pr = random_problem(30, 4, 41, false);
        let st = CoxState::from_beta(&pr, &[0.1, -0.4, 0.2, 0.0]);
        let h = beta_hessian(&pr, &st);
        for l in 0..4 {
            let (_, d2) = coord_d1_d2(&pr, &st, l);
            assert!((h.get(l, l) - d2).abs() < 1e-8, "{} vs {}", h.get(l, l), d2);
        }
        // Symmetry.
        for a in 0..4 {
            for b in 0..4 {
                assert!((h.get(a, b) - h.get(b, a)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn beta_hessian_matches_finite_difference_gradient() {
        let pr = random_problem(20, 3, 43, false);
        let beta = vec![0.2, 0.1, -0.3];
        let st = CoxState::from_beta(&pr, &beta);
        let h = beta_hessian(&pr, &st);
        let eps = 1e-5;
        for j in 0..3 {
            let mut bp = beta.clone();
            bp[j] += eps;
            let gp = beta_gradient(&pr, &CoxState::from_beta(&pr, &bp));
            let mut bm = beta.clone();
            bm[j] -= eps;
            let gm = beta_gradient(&pr, &CoxState::from_beta(&pr, &bm));
            for i in 0..3 {
                let fd = (gp[i] - gm[i]) / (2.0 * eps);
                assert!((h.get(i, j) - fd).abs() < 1e-4, "H[{i}{j}] {} vs {}", h.get(i, j), fd);
            }
        }
    }
}
