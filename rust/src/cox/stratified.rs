//! Stratified Cox model — one of the paper's listed extensions
//! ("we can apply our method to solve the CPH models with ...
//! stratifications \[40\]").
//!
//! Each stratum has its own baseline hazard: risk sets never cross
//! strata, so the partial likelihood is a *sum of per-stratum CPH
//! losses sharing one β*. Every per-coordinate quantity (d1, d2, d3,
//! Lipschitz constants) is therefore the sum over strata, and the whole
//! surrogate machinery applies unchanged.

use super::derivatives::{coord_d1_d2, CoordDerivs};
use super::lipschitz::{coord_lipschitz, LipschitzPair};
use super::loss::loss;
use super::problem::CoxProblem;
use super::state::CoxState;
use crate::data::SurvivalDataset;
use crate::optim::prox::{cubic_l1_step, cubic_step};
use crate::optim::{Objective, Trace};
use std::time::Instant;

/// A stratified CPH problem: one [`CoxProblem`] per stratum, shared β.
pub struct StratifiedCoxProblem {
    pub strata: Vec<CoxProblem>,
    pub p: usize,
}

impl StratifiedCoxProblem {
    /// Split a dataset by stratum labels (one label per sample).
    pub fn new(ds: &SurvivalDataset, labels: &[usize]) -> Self {
        assert_eq!(labels.len(), ds.n());
        let max_label = *labels.iter().max().expect("non-empty dataset");
        let mut strata = Vec::new();
        for s in 0..=max_label {
            let idx: Vec<usize> =
                (0..ds.n()).filter(|&i| labels[i] == s).collect();
            if idx.is_empty() {
                continue;
            }
            strata.push(CoxProblem::new(&ds.subset(&idx)));
        }
        assert!(!strata.is_empty());
        let p = ds.p();
        StratifiedCoxProblem { strata, p }
    }

    /// Combined loss Σ_s ℓ_s(β).
    pub fn loss(&self, states: &[CoxState]) -> f64 {
        self.strata.iter().zip(states).map(|(pr, st)| loss(pr, st)).sum()
    }

    /// Combined (d1, d2) at a coordinate.
    pub fn coord_d1_d2(&self, states: &[CoxState], l: usize) -> (f64, f64) {
        let mut d = (0.0, 0.0);
        for (pr, st) in self.strata.iter().zip(states) {
            let (d1, d2) = coord_d1_d2(pr, st, l);
            d.0 += d1;
            d.1 += d2;
        }
        d
    }

    /// Combined third-derivative data is never needed directly; the
    /// Lipschitz constants add across strata (sums of bounded terms).
    pub fn lipschitz(&self, l: usize) -> LipschitzPair {
        let mut out = LipschitzPair::default();
        for pr in &self.strata {
            let lp = coord_lipschitz(pr, l);
            out.l2 += lp.l2;
            out.l3 += lp.l3;
        }
        out
    }

    /// States at β = 0 for every stratum.
    pub fn zero_states(&self) -> Vec<CoxState> {
        self.strata.iter().map(CoxState::zeros).collect()
    }

    /// Fit by cubic-surrogate coordinate descent (shared β).
    pub fn fit(
        &self,
        obj: Objective,
        max_sweeps: usize,
        tol: f64,
    ) -> (Vec<f64>, Trace) {
        let mut states = self.zero_states();
        let mut beta = vec![0.0; self.p];
        let lip: Vec<LipschitzPair> = (0..self.p).map(|l| self.lipschitz(l)).collect();
        let mut trace = Trace::default();
        let start = Instant::now();
        let mut prev = f64::INFINITY;
        for sweep in 0..max_sweeps {
            for l in 0..self.p {
                let (d1, d2) = self.coord_d1_d2(&states, l);
                let a = d1 + 2.0 * obj.l2 * beta[l];
                let b = (d2 + 2.0 * obj.l2).max(0.0);
                if b <= 0.0 && lip[l].l3 <= 0.0 {
                    continue;
                }
                let delta = if obj.l1 > 0.0 {
                    cubic_l1_step(a, b, lip[l].l3, beta[l], obj.l1)
                } else {
                    cubic_step(a, b, lip[l].l3)
                };
                if delta != 0.0 {
                    beta[l] += delta;
                    for (pr, st) in self.strata.iter().zip(states.iter_mut()) {
                        st.update_coord(pr, l, delta);
                        // update_coord also moves st.beta; keep it in sync
                        // (harmless — states' beta is not read here).
                    }
                }
            }
            let base = self.loss(&states);
            let pen = obj.l1 * beta.iter().map(|b| b.abs()).sum::<f64>()
                + obj.l2 * beta.iter().map(|b| b * b).sum::<f64>();
            let val = base + pen;
            trace.push(sweep, start, val);
            if prev.is_finite() && (prev - val).abs() < tol * (prev.abs() + 1.0) {
                trace.converged = true;
                break;
            }
            prev = val;
        }
        (beta, trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::util::rng::Rng;

    /// Two strata with *different baseline hazards* but a shared β.
    fn stratified_ds(n_per: usize, seed: u64, beta: f64) -> (SurvivalDataset, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let n = 2 * n_per;
        let mut x = Vec::with_capacity(n);
        let mut time = Vec::with_capacity(n);
        let mut event = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let s = i % 2;
            let xv = rng.normal();
            // Stratum 1's baseline is 20x faster.
            let base = if s == 0 { 1.0 } else { 20.0 };
            time.push(rng.exponential() / (base * (beta * xv).exp()));
            event.push(rng.bernoulli(0.85));
            x.push(xv);
            labels.push(s);
        }
        (
            SurvivalDataset::new(Matrix::from_columns(&[x]), time, event, "strat"),
            labels,
        )
    }

    #[test]
    fn strata_partition_samples() {
        let (ds, labels) = stratified_ds(30, 1, 0.5);
        let sp = StratifiedCoxProblem::new(&ds, &labels);
        assert_eq!(sp.strata.len(), 2);
        assert_eq!(sp.strata[0].n() + sp.strata[1].n(), 60);
    }

    #[test]
    fn monotone_and_recovers_shared_effect() {
        let (ds, labels) = stratified_ds(300, 2, 0.8);
        let sp = StratifiedCoxProblem::new(&ds, &labels);
        let (beta, trace) = sp.fit(Objective { l1: 0.0, l2: 0.1 }, 200, 1e-10);
        assert!(trace.monotone(1e-9));
        assert!(
            (beta[0] - 0.8).abs() < 0.2,
            "stratified fit should recover β≈0.8, got {}",
            beta[0]
        );
    }

    #[test]
    fn unstratified_fit_is_biased_by_baseline_mixture() {
        // Ignoring strata mixes two very different baselines; the
        // stratified estimate must be at least as close to the truth.
        let (ds, labels) = stratified_ds(300, 3, 0.8);
        let sp = StratifiedCoxProblem::new(&ds, &labels);
        let (beta_s, _) = sp.fit(Objective { l1: 0.0, l2: 0.1 }, 200, 1e-10);
        use crate::optim::{CubicSurrogate, FitConfig, Optimizer};
        let pr = CoxProblem::new(&ds);
        let res = CubicSurrogate
            .fit(
                &pr,
                &FitConfig {
                    objective: Objective { l1: 0.0, l2: 0.1 },
                    max_iters: 200,
                    tol: 1e-10,
                    ..Default::default()
                },
            )
            .unwrap();
        let err_s = (beta_s[0] - 0.8).abs();
        let err_u = (res.beta[0] - 0.8).abs();
        assert!(err_s <= err_u + 0.05, "stratified {err_s} vs pooled {err_u}");
    }

    #[test]
    fn single_stratum_matches_plain_cox() {
        let (ds, _) = stratified_ds(100, 4, 0.5);
        let labels = vec![0usize; ds.n()];
        let sp = StratifiedCoxProblem::new(&ds, &labels);
        let (beta_s, _) = sp.fit(Objective { l1: 0.0, l2: 1.0 }, 300, 1e-12);
        use crate::optim::{CubicSurrogate, FitConfig, Optimizer};
        let pr = CoxProblem::new(&ds);
        let res = CubicSurrogate
            .fit(
                &pr,
                &FitConfig {
                    objective: Objective { l1: 0.0, l2: 1.0 },
                    max_iters: 300,
                    tol: 1e-12,
                    ..Default::default()
                },
            )
            .unwrap();
        assert!((beta_s[0] - res.beta[0]).abs() < 1e-6);
    }
}
