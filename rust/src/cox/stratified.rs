//! Stratified Cox model — one of the paper's listed extensions
//! ("we can apply our method to solve the CPH models with ...
//! stratifications \[40\]").
//!
//! Each stratum has its own baseline hazard: risk sets never cross
//! strata, so the partial likelihood is a *sum of per-stratum CPH
//! losses sharing one β*. Every per-coordinate quantity (d1, d2, d3,
//! Lipschitz constants) is therefore the sum over strata, and the whole
//! surrogate machinery applies unchanged.

use super::derivatives::{self, coord_d1_d2, coord_d1_d2_ws, Workspace};
use super::lipschitz::{coord_lipschitz, LipschitzPair};
use super::loss::loss;
use super::problem::CoxProblem;
use super::state::CoxState;
use crate::data::SurvivalDataset;
use crate::optim::prox::{cubic_l1_step, cubic_step};
use crate::optim::{Objective, Trace};
use crate::util::compute::ResolvedCompute;
use crate::util::parallel::{num_threads, par_for_each_mut_workers, par_map_workers};
use std::time::Instant;

/// Minimum total sample count before per-*sweep* work (loss, the
/// Lipschitz precompute) fans out across threads — these spawn once per
/// sweep, so a modest size already amortizes the fork-join.
const PAR_MIN_N: usize = 16_384;

/// Minimum total sample count before per-*coordinate* work (the (d1,d2)
/// pass and the η/w update after a step) fans out. These spawn fresh
/// scoped threads for every coordinate of every sweep, so the per-stratum
/// pass must be well past the ~tens-of-µs spawn cost to win.
const PAR_COORD_MIN_N: usize = 1 << 18;

/// A stratified CPH problem: one [`CoxProblem`] per stratum, shared β.
pub struct StratifiedCoxProblem {
    pub strata: Vec<CoxProblem>,
    pub p: usize,
}

impl StratifiedCoxProblem {
    /// Split a dataset by stratum labels (one label per sample).
    pub fn new(ds: &SurvivalDataset, labels: &[usize]) -> Self {
        assert_eq!(labels.len(), ds.n());
        let max_label = *labels.iter().max().expect("non-empty dataset");
        let mut strata = Vec::new();
        for s in 0..=max_label {
            let idx: Vec<usize> =
                (0..ds.n()).filter(|&i| labels[i] == s).collect();
            if idx.is_empty() {
                continue;
            }
            strata.push(CoxProblem::new(&ds.subset(&idx)));
        }
        assert!(!strata.is_empty());
        let p = ds.p();
        StratifiedCoxProblem { strata, p }
    }

    /// Total sample count across strata.
    pub fn total_n(&self) -> usize {
        self.strata.iter().map(|s| s.n()).sum()
    }

    /// Whether once-per-sweep fan-out pays for itself on this problem.
    /// Reads the ambient thread count; fit loops hoist the decision via
    /// [`Self::parallel_with`] instead of calling this per sweep.
    fn parallel(&self) -> bool {
        self.parallel_with(num_threads())
    }

    /// [`Self::parallel`] from an explicit thread budget (shape-only
    /// decision once the caller resolved its `Compute`).
    fn parallel_with(&self, threads: usize) -> bool {
        self.strata.len() > 1 && self.total_n() >= PAR_MIN_N && threads > 1
    }

    /// Whether once-per-coordinate fan-out pays for itself (much higher
    /// bar: thread spawn cost recurs p times per sweep).
    fn parallel_coord(&self) -> bool {
        self.parallel_coord_with(num_threads())
    }

    /// [`Self::parallel_coord`] from an explicit thread budget.
    fn parallel_coord_with(&self, threads: usize) -> bool {
        self.strata.len() > 1 && self.total_n() >= PAR_COORD_MIN_N && threads > 1
    }

    /// Combined loss Σ_s ℓ_s(β) — per-stratum losses fanned across
    /// threads when the problem is big enough.
    pub fn loss(&self, states: &[CoxState]) -> f64 {
        self.loss_with(states, num_threads())
    }

    /// [`Self::loss`] with an explicit thread budget, for fit loops that
    /// resolved their `Compute` once up front.
    fn loss_with(&self, states: &[CoxState], threads: usize) -> f64 {
        if self.parallel_with(threads) {
            let idx: Vec<usize> = (0..self.strata.len()).collect();
            par_map_workers(&idx, threads, |&s| loss(&self.strata[s], &states[s]))
                .iter()
                .sum()
        } else {
            self.strata.iter().zip(states).map(|(pr, st)| loss(pr, st)).sum()
        }
    }

    /// Combined (d1, d2) at a coordinate.
    pub fn coord_d1_d2(&self, states: &[CoxState], l: usize) -> (f64, f64) {
        let mut d = (0.0, 0.0);
        for (pr, st) in self.strata.iter().zip(states) {
            let (d1, d2) = coord_d1_d2(pr, st, l);
            d.0 += d1;
            d.1 += d2;
        }
        d
    }

    /// Combined (d1, d2) through one cached [`Workspace`] per stratum,
    /// fanned across strata when the problem is big enough. The
    /// per-stratum sum order is fixed, so the result does not depend on
    /// the thread count.
    pub fn coord_d1_d2_ws(
        &self,
        states: &[CoxState],
        wss: &mut [Workspace],
        l: usize,
    ) -> (f64, f64) {
        let workers = if self.parallel_coord() { num_threads() } else { 1 };
        self.coord_d1_d2_ws_with(states, wss, l, workers)
    }

    /// [`Self::coord_d1_d2_ws`] with the fan-out decision hoisted by the
    /// caller (the fit loop evaluates it once, not per coordinate);
    /// `workers <= 1` runs sequentially.
    fn coord_d1_d2_ws_with(
        &self,
        states: &[CoxState],
        wss: &mut [Workspace],
        l: usize,
        workers: usize,
    ) -> (f64, f64) {
        assert_eq!(wss.len(), self.strata.len());
        if workers > 1 {
            struct Cell<'a> {
                ws: &'a mut Workspace,
                out: (f64, f64),
            }
            let mut cells: Vec<Cell> =
                wss.iter_mut().map(|ws| Cell { ws, out: (0.0, 0.0) }).collect();
            par_for_each_mut_workers(&mut cells, workers, |s, cell| {
                cell.out = coord_d1_d2_ws(&self.strata[s], &states[s], cell.ws, l);
            });
            cells.iter().fold((0.0, 0.0), |acc, c| (acc.0 + c.out.0, acc.1 + c.out.1))
        } else {
            let mut d = (0.0, 0.0);
            for ((pr, st), ws) in self.strata.iter().zip(states).zip(wss.iter_mut()) {
                let (d1, d2) = coord_d1_d2_ws(pr, st, ws, l);
                d.0 += d1;
                d.1 += d2;
            }
            d
        }
    }

    /// Batched (d1\[p\], d2\[p\]) across all strata: one blocked parallel
    /// pass per stratum (each fanned over feature blocks), summed
    /// coordinate-wise.
    pub fn all_coord_d1_d2(
        &self,
        states: &[CoxState],
        wss: &mut [Workspace],
    ) -> (Vec<f64>, Vec<f64>) {
        assert_eq!(wss.len(), self.strata.len());
        let mut d1 = vec![0.0; self.p];
        let mut d2 = vec![0.0; self.p];
        for ((pr, st), ws) in self.strata.iter().zip(states).zip(wss.iter_mut()) {
            let (a, b) = derivatives::all_coord_d1_d2(pr, st, ws);
            for l in 0..self.p {
                d1[l] += a[l];
                d2[l] += b[l];
            }
        }
        (d1, d2)
    }

    /// One workspace per stratum (cache keys are per-state, so these can
    /// be reused across any number of sweeps).
    pub fn workspaces(&self) -> Vec<Workspace> {
        self.strata.iter().map(|_| Workspace::default()).collect()
    }

    /// Combined third-derivative data is never needed directly; the
    /// Lipschitz constants add across strata (sums of bounded terms).
    pub fn lipschitz(&self, l: usize) -> LipschitzPair {
        let mut out = LipschitzPair::default();
        for pr in &self.strata {
            let lp = coord_lipschitz(pr, l);
            out.l2 += lp.l2;
            out.l3 += lp.l3;
        }
        out
    }

    /// States at β = 0 for every stratum.
    pub fn zero_states(&self) -> Vec<CoxState> {
        self.strata.iter().map(CoxState::zeros).collect()
    }

    /// Fit by cubic-surrogate coordinate descent (shared β). Every
    /// per-stratum quantity — Lipschitz constants, (d1, d2), the η/w
    /// updates after a step, the loss — fans out across threads when the
    /// problem is big enough, through one cached [`Workspace`] per
    /// stratum.
    pub fn fit(
        &self,
        obj: Objective,
        max_sweeps: usize,
        tol: f64,
    ) -> (Vec<f64>, Trace) {
        self.fit_with_compute(obj, max_sweeps, tol, &ResolvedCompute::ambient())
    }

    /// [`Self::fit`] with an explicitly resolved [`ResolvedCompute`]: the
    /// thread budget is fixed here, once — the sweep and coordinate loops
    /// below never consult the environment again (the old code re-read
    /// `FASTSURVIVAL_THREADS` on every loss/derivative fan-out decision,
    /// i.e. several times per sweep).
    pub fn fit_with_compute(
        &self,
        obj: Objective,
        max_sweeps: usize,
        tol: f64,
        compute: &ResolvedCompute,
    ) -> (Vec<f64>, Trace) {
        let threads = compute.threads;
        let mut states = self.zero_states();
        let mut wss = self.workspaces();
        let mut beta = vec![0.0; self.p];
        let lip: Vec<LipschitzPair> = if self.parallel_with(threads) {
            let idx: Vec<usize> = (0..self.p).collect();
            par_map_workers(&idx, threads, |&l| self.lipschitz(l))
        } else {
            (0..self.p).map(|l| self.lipschitz(l)).collect()
        };
        let mut trace = Trace::default();
        let start = Instant::now();
        let mut prev = f64::INFINITY;
        // Loop-invariant fan-out decisions, hoisted out of the hot
        // coordinate loop.
        let coord_workers = if self.parallel_coord_with(threads) { threads } else { 1 };
        for sweep in 0..max_sweeps {
            for l in 0..self.p {
                let (d1, d2) =
                    self.coord_d1_d2_ws_with(&states, &mut wss, l, coord_workers);
                let a = d1 + 2.0 * obj.l2 * beta[l];
                let b = (d2 + 2.0 * obj.l2).max(0.0);
                if b <= 0.0 && lip[l].l3 <= 0.0 {
                    continue;
                }
                let delta = if obj.l1 > 0.0 {
                    cubic_l1_step(a, b, lip[l].l3, beta[l], obj.l1)
                } else {
                    cubic_step(a, b, lip[l].l3)
                };
                if delta != 0.0 {
                    beta[l] += delta;
                    // update_coord also moves st.beta; keep it in sync
                    // (harmless — states' beta is not read here).
                    if coord_workers > 1 {
                        par_for_each_mut_workers(&mut states, coord_workers, |s, st| {
                            st.update_coord(&self.strata[s], l, delta);
                        });
                    } else {
                        for (pr, st) in self.strata.iter().zip(states.iter_mut()) {
                            st.update_coord(pr, l, delta);
                        }
                    }
                }
            }
            let base = self.loss_with(&states, threads);
            let pen = obj.l1 * beta.iter().map(|b| b.abs()).sum::<f64>()
                + obj.l2 * beta.iter().map(|b| b * b).sum::<f64>();
            let val = base + pen;
            trace.push(sweep, start, val);
            if prev.is_finite() && (prev - val).abs() < tol * (prev.abs() + 1.0) {
                trace.converged = true;
                break;
            }
            prev = val;
        }
        (beta, trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::util::rng::Rng;

    /// Two strata with *different baseline hazards* but a shared β.
    fn stratified_ds(n_per: usize, seed: u64, beta: f64) -> (SurvivalDataset, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let n = 2 * n_per;
        let mut x = Vec::with_capacity(n);
        let mut time = Vec::with_capacity(n);
        let mut event = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let s = i % 2;
            let xv = rng.normal();
            // Stratum 1's baseline is 20x faster.
            let base = if s == 0 { 1.0 } else { 20.0 };
            time.push(rng.exponential() / (base * (beta * xv).exp()));
            event.push(rng.bernoulli(0.85));
            x.push(xv);
            labels.push(s);
        }
        (
            SurvivalDataset::new(Matrix::from_columns(&[x]), time, event, "strat"),
            labels,
        )
    }

    #[test]
    fn strata_partition_samples() {
        let (ds, labels) = stratified_ds(30, 1, 0.5);
        let sp = StratifiedCoxProblem::new(&ds, &labels);
        assert_eq!(sp.strata.len(), 2);
        assert_eq!(sp.strata[0].n() + sp.strata[1].n(), 60);
    }

    #[test]
    fn batched_and_cached_passes_match_sequential() {
        let (ds, labels) = stratified_ds(40, 9, 0.6);
        let sp = StratifiedCoxProblem::new(&ds, &labels);
        let mut states = sp.zero_states();
        // Move off β = 0 so the risk-set weights are nontrivial.
        for (pr, st) in sp.strata.iter().zip(states.iter_mut()) {
            st.update_coord(pr, 0, 0.3);
        }
        let mut wss = sp.workspaces();
        let (b1, b2) = sp.all_coord_d1_d2(&states, &mut wss);
        for l in 0..sp.p {
            let (d1, d2) = sp.coord_d1_d2(&states, l);
            assert!((b1[l] - d1).abs() < 1e-10, "batched d1: {} vs {d1}", b1[l]);
            assert!((b2[l] - d2).abs() < 1e-10, "batched d2: {} vs {d2}", b2[l]);
            let (c1, c2) = sp.coord_d1_d2_ws(&states, &mut wss, l);
            assert!((c1 - d1).abs() < 1e-10, "cached d1: {c1} vs {d1}");
            assert!((c2 - d2).abs() < 1e-10, "cached d2: {c2} vs {d2}");
        }
    }

    #[test]
    fn monotone_and_recovers_shared_effect() {
        let (ds, labels) = stratified_ds(300, 2, 0.8);
        let sp = StratifiedCoxProblem::new(&ds, &labels);
        let (beta, trace) = sp.fit(Objective { l1: 0.0, l2: 0.1 }, 200, 1e-10);
        assert!(trace.monotone(1e-9));
        assert!(
            (beta[0] - 0.8).abs() < 0.2,
            "stratified fit should recover β≈0.8, got {}",
            beta[0]
        );
    }

    #[test]
    fn unstratified_fit_is_biased_by_baseline_mixture() {
        // Ignoring strata mixes two very different baselines; the
        // stratified estimate must be at least as close to the truth.
        let (ds, labels) = stratified_ds(300, 3, 0.8);
        let sp = StratifiedCoxProblem::new(&ds, &labels);
        let (beta_s, _) = sp.fit(Objective { l1: 0.0, l2: 0.1 }, 200, 1e-10);
        use crate::optim::{CubicSurrogate, FitConfig, Optimizer};
        let pr = CoxProblem::new(&ds);
        let res = CubicSurrogate
            .fit(
                &pr,
                &FitConfig {
                    objective: Objective { l1: 0.0, l2: 0.1 },
                    max_iters: 200,
                    tol: 1e-10,
                    ..Default::default()
                },
            )
            .unwrap();
        let err_s = (beta_s[0] - 0.8).abs();
        let err_u = (res.beta[0] - 0.8).abs();
        assert!(err_s <= err_u + 0.05, "stratified {err_s} vs pooled {err_u}");
    }

    #[test]
    fn single_stratum_matches_plain_cox() {
        let (ds, _) = stratified_ds(100, 4, 0.5);
        let labels = vec![0usize; ds.n()];
        let sp = StratifiedCoxProblem::new(&ds, &labels);
        let (beta_s, _) = sp.fit(Objective { l1: 0.0, l2: 1.0 }, 300, 1e-12);
        use crate::optim::{CubicSurrogate, FitConfig, Optimizer};
        let pr = CoxProblem::new(&ds);
        let res = CubicSurrogate
            .fit(
                &pr,
                &FitConfig {
                    objective: Objective { l1: 0.0, l2: 1.0 },
                    max_iters: 300,
                    tol: 1e-12,
                    ..Default::default()
                },
            )
            .unwrap();
        assert!((beta_s[0] - res.beta[0]).abs() < 1e-6);
    }
}
