//! Mutable optimizer state: β, η = Xβ, and stabilized exp(η).
//!
//! The coordinate-descent hot path updates one β_l, then needs fresh
//! exp(η) for the next derivative pass. We store `w_k = exp(η_k − shift)`
//! with a running max-shift so no overflow occurs even when baseline
//! Newton methods push η to ±hundreds (the paper's blow-up experiments).

use super::problem::CoxProblem;
use crate::util::compute::{default_backend, KernelBackend, LANES};
use std::sync::atomic::{AtomicU64, Ordering};

/// How many incremental coordinate updates before a full recompute of w
/// from η (bounds multiplicative drift). `pub(crate)` so the sharded
/// engine — which owns its η/w as worker-sliced vectors rather than a
/// [`CoxState`] — replicates the identical rebase schedule.
pub(crate) const REFRESH_EVERY: usize = 512;

/// Rebase when |max η − shift| exceeds this span (overflow guard upward,
/// w-underflow guard downward). Shared with the sharded engine for the
/// same reason as [`REFRESH_EVERY`].
pub(crate) const REBASE_SPAN: f64 = 30.0;

/// Process-global monotone counter behind [`CoxState::version`]. Every
/// mutation of any state takes a fresh value, so version tags never
/// collide across distinct states — a [`super::derivatives::Workspace`]
/// cache keyed on the tag stays valid even when one workspace serves
/// many states (the beam-search pattern).
static STATE_VERSION: AtomicU64 = AtomicU64::new(1);

fn next_version() -> u64 {
    STATE_VERSION.fetch_add(1, Ordering::Relaxed)
}

#[derive(Clone, Debug)]
pub struct CoxState {
    pub beta: Vec<f64>,
    /// Linear predictor per sorted sample. If you mutate this directly
    /// (instead of through [`CoxState::update_coord`] /
    /// [`CoxState::set_beta`]), call [`CoxState::refresh_w`] afterwards
    /// so w and the cache version stay consistent.
    pub eta: Vec<f64>,
    /// Stabilized hazard weights w = exp(η − shift).
    pub w: Vec<f64>,
    /// Current stabilization shift (max η at last refresh).
    pub shift: f64,
    updates_since_refresh: usize,
    /// Cache tag; see [`CoxState::version`].
    version: u64,
}

impl CoxState {
    /// State at β = 0 (the paper's initialization for every method).
    pub fn zeros(problem: &CoxProblem) -> Self {
        let n = problem.n();
        CoxState {
            beta: vec![0.0; problem.p()],
            eta: vec![0.0; n],
            w: vec![1.0; n],
            shift: 0.0,
            updates_since_refresh: 0,
            version: next_version(),
        }
    }

    /// State at β = 0 for an explicit problem shape — the out-of-core
    /// driver has no [`CoxProblem`], only a chunked store with the same
    /// sorted-sample geometry.
    pub fn zeros_sized(n: usize, p: usize) -> Self {
        CoxState {
            beta: vec![0.0; p],
            eta: vec![0.0; n],
            w: vec![1.0; n],
            shift: 0.0,
            updates_since_refresh: 0,
            version: next_version(),
        }
    }

    /// State from an explicit (β, η = Xβ) pair computed elsewhere — the
    /// chunked store driver accumulates η with one pass over on-disk
    /// feature chunks and hands it over here. `refresh_w` derives w and
    /// the stabilization shift exactly as [`CoxState::from_beta`] does.
    pub fn from_eta(beta: Vec<f64>, eta: Vec<f64>) -> Self {
        let mut s = CoxState {
            beta,
            eta,
            w: Vec::new(),
            shift: 0.0,
            updates_since_refresh: 0,
            version: 0,
        };
        s.refresh_w();
        s
    }

    /// State at a given β (recomputes η = Xβ).
    pub fn from_beta(problem: &CoxProblem, beta: &[f64]) -> Self {
        assert_eq!(beta.len(), problem.p());
        let eta = problem.x.matvec(beta);
        let mut s = CoxState {
            beta: beta.to_vec(),
            eta,
            w: Vec::new(),
            shift: 0.0,
            updates_since_refresh: 0,
            version: 0,
        };
        s.refresh_w();
        s
    }

    /// Monotone cache tag: changes whenever η/w change, never repeats
    /// across states. [`super::derivatives::Workspace`] keys its
    /// per-group risk-set weight cache on this, so any number of
    /// derivative passes at one η share a single prefix accumulation.
    #[inline]
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Recompute w = exp(η − max η) from scratch.
    pub fn refresh_w(&mut self) {
        let m = self.eta.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let m = if m.is_finite() { m } else { 0.0 };
        self.shift = m;
        self.w = self.eta.iter().map(|&e| (e - m).exp()).collect();
        self.updates_since_refresh = 0;
        self.version = next_version();
    }

    /// Apply a single-coordinate step β_l += Δ, updating η and w
    /// incrementally: only nonzero entries of x_l are re-exponentiated
    /// (a full recompute is n exp() calls; this is nnz(x_l) — or one,
    /// for binary columns). The cheap compare-only scan keeps the exact
    /// max η so both rebase guards fire exactly as on a full recompute.
    ///
    /// Tiny increments take a cubic-Taylor fast path instead of `exp()`:
    /// for |z| < 1e-4 the truncation error of `1 + z(1 + z(1/2 + z/6))`
    /// is below z⁴/24 ≈ 4e-18 relative — under one ulp, so the result is
    /// numerically indistinguishable while skipping the transcendental.
    /// Warm-started path solves spend most of their steps here.
    pub fn update_coord(&mut self, problem: &CoxProblem, l: usize, delta: f64) {
        self.update_coord_col(problem.x.col(l), problem.col_binary[l], l, delta)
    }

    /// [`CoxState::update_coord`] from an explicit column slice (and its
    /// all-binary flag) instead of a [`CoxProblem`] — the out-of-core
    /// driver streams columns from disk and applies the identical
    /// incremental update, so chunked and in-memory fits share every
    /// floating-point operation on this hot path.
    pub fn update_coord_col(&mut self, col: &[f64], binary: bool, l: usize, delta: f64) {
        self.update_coord_col_b(default_backend(), col, binary, l, delta)
    }

    /// [`CoxState::update_coord_col`] with an explicit kernel backend.
    /// The SIMD arm processes [`LANES`] samples per iteration with one
    /// independent max-η tracker per lane; every per-element operation is
    /// independent and the lane maxima fold with the same `>` comparisons
    /// the scalar scan makes, so both backends are **bitwise** identical
    /// on every input.
    pub fn update_coord_col_b(
        &mut self,
        backend: KernelBackend,
        col: &[f64],
        binary: bool,
        l: usize,
        delta: f64,
    ) {
        debug_assert_eq!(col.len(), self.eta.len());
        if delta == 0.0 {
            return;
        }
        self.beta[l] += delta;
        let max_eta = match backend {
            KernelBackend::Scalar => self.apply_coord_scalar(col, binary, delta),
            KernelBackend::Simd => self.apply_coord_lanes(col, binary, delta),
        };
        self.updates_since_refresh += 1;
        self.version = next_version();
        // Rebase if η drifted far from the shift (overflow guard upward,
        // w-underflow guard downward) or after many incremental
        // multiplies (precision guard).
        if max_eta - self.shift > REBASE_SPAN
            || max_eta - self.shift < -REBASE_SPAN
            || self.updates_since_refresh >= REFRESH_EVERY
        {
            self.refresh_w();
        }
    }

    /// The scalar re-exponentiation scan; returns the exact max η.
    fn apply_coord_scalar(&mut self, col: &[f64], binary: bool, delta: f64) -> f64 {
        apply_coord_scalar_slice(col, binary, delta, &mut self.eta, &mut self.w)
    }

    /// Lane-unrolled re-exponentiation; bitwise equal to
    /// [`CoxState::apply_coord_scalar`] (see
    /// [`apply_coord_lanes_slice`]).
    fn apply_coord_lanes(&mut self, col: &[f64], binary: bool, delta: f64) -> f64 {
        apply_coord_lanes_slice(col, binary, delta, &mut self.eta, &mut self.w)
    }

    /// Replace β wholesale (full-vector methods like Newton), recomputing
    /// η and w.
    pub fn set_beta(&mut self, problem: &CoxProblem, beta: &[f64]) {
        self.beta.copy_from_slice(beta);
        self.eta = problem.x.matvec(beta);
        self.refresh_w();
    }
}

/// [`apply_coord_scalar_slice`]/[`apply_coord_lanes_slice`] behind a
/// backend switch — the entry the sharded engine's workers call on the
/// η/w slice ranges they own.
pub(crate) fn apply_coord_slice_b(
    backend: KernelBackend,
    col: &[f64],
    binary: bool,
    delta: f64,
    eta: &mut [f64],
    w: &mut [f64],
) -> f64 {
    match backend {
        KernelBackend::Scalar => apply_coord_scalar_slice(col, binary, delta, eta, w),
        KernelBackend::Simd => apply_coord_lanes_slice(col, binary, delta, eta, w),
    }
}

/// The scalar re-exponentiation scan over explicit η/w slices; returns
/// the exact max η over the slice. Lifted out of [`CoxState`] so the
/// sharded engine's workers can apply the identical update to the row
/// ranges they own: every operation is elementwise and slice maxima
/// fold with the same `>` comparisons a whole-array scan makes, so any
/// partition of the rows into contiguous slices reproduces the
/// whole-array update bitwise.
fn apply_coord_scalar_slice(
    col: &[f64],
    binary: bool,
    delta: f64,
    eta: &mut [f64],
    w: &mut [f64],
) -> f64 {
    let mut max_eta = f64::NEG_INFINITY;
    if binary {
        // Binary column (the Sec-4.2 binarized regime): every nonzero
        // entry shares one multiplicative factor exp(Δ) — one exp()
        // for the whole update instead of one per sample.
        let factor = delta.exp();
        for (k, &xkl) in col.iter().enumerate() {
            if xkl != 0.0 {
                eta[k] += delta;
                w[k] *= factor;
            }
            if eta[k] > max_eta {
                max_eta = eta[k];
            }
        }
    } else {
        for (k, &xkl) in col.iter().enumerate() {
            if xkl != 0.0 {
                let z = delta * xkl;
                eta[k] += z;
                w[k] *= if z.abs() < 1e-4 {
                    1.0 + z * (1.0 + z * (0.5 + z * (1.0 / 6.0)))
                } else {
                    z.exp()
                };
            }
            if eta[k] > max_eta {
                max_eta = eta[k];
            }
        }
    }
    max_eta
}

/// Lane-unrolled re-exponentiation over explicit η/w slices: [`LANES`]
/// independent update chains plus [`LANES`] max-η trackers folded at the
/// end with the same `>` comparisons the scalar scan makes (max is
/// associative and `>` never admits NaN in either order), so the result
/// is bitwise equal to [`apply_coord_scalar_slice`] — and, because the
/// per-element work is independent of the lane grouping, bitwise
/// invariant to how the rows are sliced across workers.
fn apply_coord_lanes_slice(
    col: &[f64],
    binary: bool,
    delta: f64,
    eta: &mut [f64],
    w: &mut [f64],
) -> f64 {
    let n = col.len();
    let whole = n - n % LANES;
    let mut maxes = [f64::NEG_INFINITY; LANES];
    if binary {
        let factor = delta.exp();
        let mut k = 0;
        while k < whole {
            for (j, m) in maxes.iter_mut().enumerate() {
                let i = k + j;
                if col[i] != 0.0 {
                    eta[i] += delta;
                    w[i] *= factor;
                }
                if eta[i] > *m {
                    *m = eta[i];
                }
            }
            k += LANES;
        }
        for i in whole..n {
            if col[i] != 0.0 {
                eta[i] += delta;
                w[i] *= factor;
            }
            if eta[i] > maxes[0] {
                maxes[0] = eta[i];
            }
        }
    } else {
        let mut k = 0;
        while k < whole {
            for (j, m) in maxes.iter_mut().enumerate() {
                let i = k + j;
                let xkl = col[i];
                if xkl != 0.0 {
                    let z = delta * xkl;
                    eta[i] += z;
                    w[i] *= if z.abs() < 1e-4 {
                        1.0 + z * (1.0 + z * (0.5 + z * (1.0 / 6.0)))
                    } else {
                        z.exp()
                    };
                }
                if eta[i] > *m {
                    *m = eta[i];
                }
            }
            k += LANES;
        }
        for i in whole..n {
            let xkl = col[i];
            if xkl != 0.0 {
                let z = delta * xkl;
                eta[i] += z;
                w[i] *= if z.abs() < 1e-4 {
                    1.0 + z * (1.0 + z * (0.5 + z * (1.0 / 6.0)))
                } else {
                    z.exp()
                };
            }
            if eta[i] > maxes[0] {
                maxes[0] = eta[i];
            }
        }
    }
    let mut max_eta = f64::NEG_INFINITY;
    for &m in &maxes {
        if m > max_eta {
            max_eta = m;
        }
    }
    max_eta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SurvivalDataset;
    use crate::linalg::Matrix;

    fn problem() -> CoxProblem {
        let x = Matrix::from_columns(&[
            vec![1.0, 0.0, 1.0, 0.5],
            vec![0.0, 1.0, 1.0, -0.5],
        ]);
        let ds = SurvivalDataset::new(
            x,
            vec![4.0, 3.0, 2.0, 1.0],
            vec![true, true, false, true],
            "t",
        );
        CoxProblem::new(&ds)
    }

    #[test]
    fn zeros_state() {
        let p = problem();
        let s = CoxState::zeros(&p);
        assert!(s.w.iter().all(|&w| w == 1.0));
        assert_eq!(s.shift, 0.0);
    }

    #[test]
    fn incremental_matches_full_recompute() {
        let p = problem();
        let mut s = CoxState::zeros(&p);
        s.update_coord(&p, 0, 0.7);
        s.update_coord(&p, 1, -0.3);
        s.update_coord(&p, 0, 0.1);
        let full = CoxState::from_beta(&p, &s.beta);
        for k in 0..p.n() {
            assert!((s.eta[k] - full.eta[k]).abs() < 1e-12);
            let wa = s.w[k] * s.shift.exp();
            let wb = full.w[k] * full.shift.exp();
            assert!((wa - wb).abs() / wb.max(1e-300) < 1e-10);
        }
    }

    #[test]
    fn large_eta_does_not_overflow() {
        let p = problem();
        let mut s = CoxState::zeros(&p);
        for _ in 0..50 {
            s.update_coord(&p, 0, 20.0); // η up to ~1000
        }
        assert!(s.w.iter().all(|w| w.is_finite()));
        assert!(s.w.iter().cloned().fold(0.0f64, f64::max) <= 1.0 + 1e-12);
    }

    #[test]
    fn version_changes_on_every_mutation_and_never_collides() {
        let p = problem();
        let mut s = CoxState::zeros(&p);
        let v0 = s.version();
        s.update_coord(&p, 0, 0.0); // no-op step: w unchanged, tag stable
        assert_eq!(s.version(), v0);
        s.update_coord(&p, 0, 0.5);
        let v1 = s.version();
        assert_ne!(v1, v0);
        s.refresh_w();
        assert_ne!(s.version(), v1);
        // Distinct states never share a tag (global counter).
        let other = CoxState::zeros(&p);
        assert_ne!(other.version(), s.version());
        // A clone shares w bit-for-bit, so sharing the tag is correct —
        // until either side mutates.
        let mut c = s.clone();
        assert_eq!(c.version(), s.version());
        c.update_coord(&p, 1, 0.1);
        assert_ne!(c.version(), s.version());
    }

    #[test]
    fn column_slice_update_matches_problem_update() {
        let p = problem();
        let mut a = CoxState::zeros(&p);
        let mut b = CoxState::zeros_sized(p.n(), p.p());
        for (l, d) in [(0usize, 0.7), (1, -0.3), (0, 0.1)] {
            a.update_coord(&p, l, d);
            b.update_coord_col(p.x.col(l), p.col_binary[l], l, d);
        }
        assert_eq!(a.eta, b.eta);
        assert_eq!(a.w, b.w);
        assert_eq!(a.beta, b.beta);
        assert_eq!(a.shift, b.shift);
    }

    #[test]
    fn backend_updates_are_bitwise_identical() {
        // Bigger than the toy fixture so lane chunks + tail both run, with
        // zeros sprinkled in (skip branch) and a binary column.
        let n = 37;
        let dense: Vec<f64> = (0..n)
            .map(|i| if i % 5 == 0 { 0.0 } else { ((i * 3 % 13) as f64) / 6.0 - 1.0 })
            .collect();
        let bin: Vec<f64> = (0..n).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
        let x = Matrix::from_columns(&[dense.clone(), bin.clone()]);
        let time: Vec<f64> = (0..n).map(|i| (n - i) as f64).collect();
        let event: Vec<bool> = (0..n).map(|i| i % 2 == 0).collect();
        let ds = SurvivalDataset::new(x, time, event, "b");
        let p = CoxProblem::new(&ds);
        let mut a = CoxState::zeros(&p);
        let mut b = CoxState::zeros(&p);
        // Mix tiny deltas (Taylor path), big deltas (exp path), and the
        // binary column; include a delta large enough to trigger a rebase.
        for (l, d) in [(0usize, 5e-5), (1, 0.8), (0, -0.4), (0, 35.0), (1, -0.2)] {
            a.update_coord_col_b(KernelBackend::Scalar, p.x.col(l), p.col_binary[l], l, d);
            b.update_coord_col_b(KernelBackend::Simd, p.x.col(l), p.col_binary[l], l, d);
            assert_eq!(a.eta, b.eta, "l={l} d={d}");
            assert_eq!(a.w, b.w, "l={l} d={d}");
            assert_eq!(a.shift, b.shift);
        }
        assert_eq!(a.beta, b.beta);
    }

    #[test]
    fn sliced_apply_is_partition_invariant() {
        // Workers in the sharded engine apply a coordinate step to the
        // η/w slice ranges they own; any contiguous partition must
        // reproduce the whole-array update bitwise, including the folded
        // max-η that drives the rebase guards.
        let n = 53;
        let dense: Vec<f64> = (0..n)
            .map(|i| if i % 7 == 0 { 0.0 } else { ((i * 5 % 17) as f64) / 4.0 - 2.0 })
            .collect();
        let bin: Vec<f64> = (0..n).map(|i| if i % 4 == 0 { 1.0 } else { 0.0 }).collect();
        for backend in [KernelBackend::Scalar, KernelBackend::Simd] {
            for (col, binary, delta) in
                [(&dense, false, 5e-5), (&dense, false, 0.9), (&bin, true, -0.6)]
            {
                let base_eta: Vec<f64> = (0..n).map(|i| (i as f64) * 0.01 - 0.2).collect();
                let base_w: Vec<f64> = base_eta.iter().map(|e| e.exp()).collect();
                let mut whole_eta = base_eta.clone();
                let mut whole_w = base_w.clone();
                let whole_max =
                    apply_coord_slice_b(backend, col, binary, delta, &mut whole_eta, &mut whole_w);
                for cuts in [vec![0, n], vec![0, 19, n], vec![0, 8, 8, 31, n]] {
                    let mut eta = base_eta.clone();
                    let mut w = base_w.clone();
                    let mut max = f64::NEG_INFINITY;
                    for pair in cuts.windows(2) {
                        let (a, b) = (pair[0], pair[1]);
                        let m = apply_coord_slice_b(
                            backend,
                            &col[a..b],
                            binary,
                            delta,
                            &mut eta[a..b],
                            &mut w[a..b],
                        );
                        if m > max {
                            max = m;
                        }
                    }
                    assert_eq!(eta, whole_eta, "{backend:?} cuts {cuts:?}");
                    assert_eq!(w, whole_w, "{backend:?} cuts {cuts:?}");
                    assert_eq!(max.to_bits(), whole_max.to_bits());
                }
            }
        }
    }

    #[test]
    fn from_eta_matches_from_beta() {
        let p = problem();
        let beta = vec![0.3, -0.2];
        let want = CoxState::from_beta(&p, &beta);
        let got = CoxState::from_eta(beta.clone(), p.x.matvec(&beta));
        assert_eq!(got.eta, want.eta);
        assert_eq!(got.w, want.w);
        assert_eq!(got.shift, want.shift);
    }

    #[test]
    fn set_beta_roundtrip() {
        let p = problem();
        let mut s = CoxState::zeros(&p);
        s.set_beta(&p, &[0.3, -0.2]);
        let expect = CoxState::from_beta(&p, &[0.3, -0.2]);
        assert_eq!(s.eta, expect.eta);
    }
}
