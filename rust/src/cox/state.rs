//! Mutable optimizer state: β, η = Xβ, and stabilized exp(η).
//!
//! The coordinate-descent hot path updates one β_l, then needs fresh
//! exp(η) for the next derivative pass. We store `w_k = exp(η_k − shift)`
//! with a running max-shift so no overflow occurs even when baseline
//! Newton methods push η to ±hundreds (the paper's blow-up experiments).

use super::problem::CoxProblem;

/// How many incremental coordinate updates before a full recompute of w
/// from η (bounds multiplicative drift).
const REFRESH_EVERY: usize = 512;

#[derive(Clone, Debug)]
pub struct CoxState {
    pub beta: Vec<f64>,
    /// Linear predictor per sorted sample.
    pub eta: Vec<f64>,
    /// Stabilized hazard weights w = exp(η − shift).
    pub w: Vec<f64>,
    /// Current stabilization shift (max η at last refresh).
    pub shift: f64,
    updates_since_refresh: usize,
}

impl CoxState {
    /// State at β = 0 (the paper's initialization for every method).
    pub fn zeros(problem: &CoxProblem) -> Self {
        let n = problem.n();
        CoxState {
            beta: vec![0.0; problem.p()],
            eta: vec![0.0; n],
            w: vec![1.0; n],
            shift: 0.0,
            updates_since_refresh: 0,
        }
    }

    /// State at a given β (recomputes η = Xβ).
    pub fn from_beta(problem: &CoxProblem, beta: &[f64]) -> Self {
        assert_eq!(beta.len(), problem.p());
        let eta = problem.x.matvec(beta);
        let mut s = CoxState {
            beta: beta.to_vec(),
            eta,
            w: Vec::new(),
            shift: 0.0,
            updates_since_refresh: 0,
        };
        s.refresh_w();
        s
    }

    /// Recompute w = exp(η − max η) from scratch.
    pub fn refresh_w(&mut self) {
        let m = self.eta.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let m = if m.is_finite() { m } else { 0.0 };
        self.shift = m;
        self.w = self.eta.iter().map(|&e| (e - m).exp()).collect();
        self.updates_since_refresh = 0;
    }

    /// Apply a single-coordinate step β_l += Δ, updating η and w
    /// incrementally. O(nnz(x_l)) when the column is sparse/binary.
    pub fn update_coord(&mut self, problem: &CoxProblem, l: usize, delta: f64) {
        if delta == 0.0 {
            return;
        }
        self.beta[l] += delta;
        let col = problem.x.col(l);
        let mut max_eta = f64::NEG_INFINITY;
        if problem.col_binary[l] {
            // Binary column (the Sec-4.2 binarized regime): every nonzero
            // entry shares one multiplicative factor exp(Δ) — one exp()
            // for the whole update instead of one per sample.
            let factor = delta.exp();
            for (k, &xkl) in col.iter().enumerate() {
                if xkl != 0.0 {
                    self.eta[k] += delta;
                    self.w[k] *= factor;
                }
                if self.eta[k] > max_eta {
                    max_eta = self.eta[k];
                }
            }
        } else {
            for (k, &xkl) in col.iter().enumerate() {
                if xkl != 0.0 {
                    self.eta[k] += delta * xkl;
                    self.w[k] *= (delta * xkl).exp();
                }
                if self.eta[k] > max_eta {
                    max_eta = self.eta[k];
                }
            }
        }
        self.updates_since_refresh += 1;
        // Rebase if η drifted far from the shift (overflow guard) or after
        // many incremental multiplies (precision guard).
        if max_eta - self.shift > 30.0
            || max_eta - self.shift < -30.0
            || self.updates_since_refresh >= REFRESH_EVERY
        {
            self.refresh_w();
        }
    }

    /// Replace β wholesale (full-vector methods like Newton), recomputing
    /// η and w.
    pub fn set_beta(&mut self, problem: &CoxProblem, beta: &[f64]) {
        self.beta.copy_from_slice(beta);
        self.eta = problem.x.matvec(beta);
        self.refresh_w();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SurvivalDataset;
    use crate::linalg::Matrix;

    fn problem() -> CoxProblem {
        let x = Matrix::from_columns(&[
            vec![1.0, 0.0, 1.0, 0.5],
            vec![0.0, 1.0, 1.0, -0.5],
        ]);
        let ds = SurvivalDataset::new(
            x,
            vec![4.0, 3.0, 2.0, 1.0],
            vec![true, true, false, true],
            "t",
        );
        CoxProblem::new(&ds)
    }

    #[test]
    fn zeros_state() {
        let p = problem();
        let s = CoxState::zeros(&p);
        assert!(s.w.iter().all(|&w| w == 1.0));
        assert_eq!(s.shift, 0.0);
    }

    #[test]
    fn incremental_matches_full_recompute() {
        let p = problem();
        let mut s = CoxState::zeros(&p);
        s.update_coord(&p, 0, 0.7);
        s.update_coord(&p, 1, -0.3);
        s.update_coord(&p, 0, 0.1);
        let full = CoxState::from_beta(&p, &s.beta);
        for k in 0..p.n() {
            assert!((s.eta[k] - full.eta[k]).abs() < 1e-12);
            let wa = s.w[k] * s.shift.exp();
            let wb = full.w[k] * full.shift.exp();
            assert!((wa - wb).abs() / wb.max(1e-300) < 1e-10);
        }
    }

    #[test]
    fn large_eta_does_not_overflow() {
        let p = problem();
        let mut s = CoxState::zeros(&p);
        for _ in 0..50 {
            s.update_coord(&p, 0, 20.0); // η up to ~1000
        }
        assert!(s.w.iter().all(|w| w.is_finite()));
        assert!(s.w.iter().cloned().fold(0.0f64, f64::max) <= 1.0 + 1e-12);
    }

    #[test]
    fn set_beta_roundtrip() {
        let p = problem();
        let mut s = CoxState::zeros(&p);
        s.set_beta(&p, &[0.3, -0.2]);
        let expect = CoxState::from_beta(&p, &[0.3, -0.2]);
        assert_eq!(s.eta, expect.eta);
    }
}
