//! Risk-set central moments (Lemma 3.2) and naive O(n²) reference
//! implementations used to validate the fast O(n) passes.

use super::derivatives::CoordDerivs;
use super::problem::CoxProblem;

/// Softmax probabilities over a risk set: a_k = e^{η_k} / Σ_{j∈R} e^{η_j}.
pub fn risk_set_probs(eta: &[f64], risk: &[usize]) -> Vec<f64> {
    let m = risk.iter().map(|&k| eta[k]).fold(f64::NEG_INFINITY, f64::max);
    let ws: Vec<f64> = risk.iter().map(|&k| (eta[k] - m).exp()).collect();
    let z: f64 = ws.iter().sum();
    ws.into_iter().map(|w| w / z).collect()
}

/// r-th central moment C_r of {x_k} under probabilities {a_k} (Eq. 10).
pub fn central_moment(a: &[f64], x: &[f64], r: u32) -> f64 {
    debug_assert_eq!(a.len(), x.len());
    let mean: f64 = a.iter().zip(x).map(|(&p, &v)| p * v).sum();
    a.iter().zip(x).map(|(&p, &v)| p * (v - mean).powi(r as i32)).sum()
}

/// Naive O(n²) loss (explicit risk sets), for testing.
pub fn naive_loss(problem: &CoxProblem, eta: &[f64]) -> f64 {
    let n = problem.n();
    let mut total = 0.0;
    for i in 0..n {
        if problem.delta[i] != 1.0 {
            continue;
        }
        let risk: Vec<usize> = (0..n).filter(|&j| problem.time[j] >= problem.time[i]).collect();
        let m = risk.iter().map(|&k| eta[k]).fold(f64::NEG_INFINITY, f64::max);
        let z: f64 = risk.iter().map(|&k| (eta[k] - m).exp()).sum();
        total += z.ln() + m - eta[i];
    }
    total
}

/// Naive O(n²) coordinate derivatives straight from Theorem 3.1.
pub fn naive_coord_derivs(problem: &CoxProblem, eta: &[f64], l: usize) -> CoordDerivs {
    let n = problem.n();
    let col = problem.x.col(l);
    let mut out = CoordDerivs::default();
    for i in 0..n {
        if problem.delta[i] != 1.0 {
            continue;
        }
        let risk: Vec<usize> = (0..n).filter(|&j| problem.time[j] >= problem.time[i]).collect();
        let a = risk_set_probs(eta, &risk);
        let xs: Vec<f64> = risk.iter().map(|&k| col[k]).collect();
        let e1: f64 = a.iter().zip(&xs).map(|(&p, &x)| p * x).sum();
        let e2: f64 = a.iter().zip(&xs).map(|(&p, &x)| p * x * x).sum();
        let e3: f64 = a.iter().zip(&xs).map(|(&p, &x)| p * x * x * x).sum();
        out.d1 += e1 - col[i];
        out.d2 += e2 - e1 * e1;
        out.d3 += e3 + 2.0 * e1.powi(3) - 3.0 * e2 * e1;
    }
    out
}

/// Naive O(n²) η-space gradient, for testing.
pub fn naive_eta_gradient(problem: &CoxProblem, eta: &[f64]) -> Vec<f64> {
    let n = problem.n();
    let mut u = vec![0.0; n];
    for i in 0..n {
        if problem.delta[i] != 1.0 {
            continue;
        }
        let risk: Vec<usize> = (0..n).filter(|&j| problem.time[j] >= problem.time[i]).collect();
        let a = risk_set_probs(eta, &risk);
        for (idx, &k) in risk.iter().enumerate() {
            u[k] += a[idx];
        }
        u[i] -= 1.0;
    }
    u
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn probs_sum_to_one() {
        let mut rng = Rng::new(3);
        let eta: Vec<f64> = (0..10).map(|_| rng.normal() * 5.0).collect();
        let risk: Vec<usize> = (0..10).collect();
        let a = risk_set_probs(&eta, &risk);
        assert!((a.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(a.iter().all(|&p| p >= 0.0));
    }

    #[test]
    fn central_moment_c1_is_zero() {
        let mut rng = Rng::new(5);
        let x: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        let raw: Vec<f64> = (0..8).map(|_| rng.uniform() + 0.1).collect();
        let z: f64 = raw.iter().sum();
        let a: Vec<f64> = raw.iter().map(|r| r / z).collect();
        assert!(central_moment(&a, &x, 1).abs() < 1e-12);
        assert!(central_moment(&a, &x, 2) >= 0.0);
    }

    /// Lemma 3.2: ∂C_r/∂β_l = C_{r+1} − r·C_2·C_{r−1}, verified by finite
    /// differences for r = 2, 3, 4 on a single risk set.
    #[test]
    fn lemma_3_2_derivative_identity() {
        let mut rng = Rng::new(9);
        let n = 12;
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let beta = 0.3_f64;
        let h = 1e-6;
        let risk: Vec<usize> = (0..n).collect();

        let moments = |b: f64| -> Vec<f64> {
            let eta: Vec<f64> = x.iter().map(|&v| b * v).collect();
            let a = risk_set_probs(&eta, &risk);
            (0..=5).map(|r| central_moment(&a, &x, r)).collect()
        };
        let c = moments(beta);
        let cp = moments(beta + h);
        let cm = moments(beta - h);
        for r in 2..=4usize {
            let fd = (cp[r] - cm[r]) / (2.0 * h);
            let analytic = c[r + 1] - (r as f64) * c[2] * c[r - 1];
            assert!(
                (fd - analytic).abs() < 1e-5,
                "r={r}: fd={fd} analytic={analytic}"
            );
        }
    }

    /// For r=2 the recursion collapses to ∂C_2 = C_3 (since C_1 = 0).
    #[test]
    fn variance_derivative_is_skewness() {
        let mut rng = Rng::new(13);
        let n = 9;
        let x: Vec<f64> = (0..n).map(|_| rng.uniform_range(-2.0, 2.0)).collect();
        let risk: Vec<usize> = (0..n).collect();
        let h = 1e-6;
        let c2 = |b: f64| {
            let eta: Vec<f64> = x.iter().map(|&v| b * v).collect();
            central_moment(&risk_set_probs(&eta, &risk), &x, 2)
        };
        let eta: Vec<f64> = x.iter().map(|&v| 0.1 * v).collect();
        let c3 = central_moment(&risk_set_probs(&eta, &risk), &x, 3);
        let fd = (c2(0.1 + h) - c2(0.1 - h)) / (2.0 * h);
        assert!((fd - c3).abs() < 1e-5, "fd={fd} c3={c3}");
    }
}
