//! Explicit Lipschitz constants (Theorem 3.4).
//!
//! `L2_l = ¼ Σ_i δ_i (max_{k∈R_i} X_kl − min_{k∈R_i} X_kl)²`  (Popoviciu)
//! `L3_l = 1/(6√3) Σ_i δ_i |max_{k∈R_i} X_kl − min_{k∈R_i} X_kl|³` (Sharma
//! et al. third-central-moment bound).
//!
//! Both depend only on the data (not β), so they are computed once per
//! fit. With descending-time order the risk sets are prefixes, so the
//! max/min over R_i are running prefix extrema — O(n) per coordinate.

use super::problem::CoxProblem;

/// Per-coordinate surrogate constants.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LipschitzPair {
    /// Bound on d²ℓ/dβ_l² ⇒ Lipschitz constant of d1 (Eq. 13).
    pub l2: f64,
    /// Bound on |d³ℓ/dβ_l³| ⇒ Lipschitz constant of d2 (Eq. 14).
    pub l3: f64,
}

const INV_6_SQRT3: f64 = 0.09622504486493764; // 1 / (6 √3)

impl LipschitzPair {
    /// Fold in one event group's contribution: `ne` events whose risk-set
    /// range of the coordinate is `range`. The one place the Theorem-3.4
    /// formulas live — [`coord_lipschitz`] and the chunked store's
    /// streaming column-stats pass both accumulate through here, in the
    /// same group order, so their constants agree bit for bit.
    #[inline]
    pub fn add_group(&mut self, ne: f64, range: f64) {
        self.l2 += ne * 0.25 * range * range;
        self.l3 += ne * INV_6_SQRT3 * range * range * range;
    }
}

/// Lipschitz constants for one coordinate, O(n).
pub fn coord_lipschitz(problem: &CoxProblem, l: usize) -> LipschitzPair {
    let col = problem.x.col(l);
    let mut hi = f64::NEG_INFINITY;
    let mut lo = f64::INFINITY;
    let mut out = LipschitzPair::default();
    for g in &problem.groups {
        for k in g.start..g.end {
            let x = col[k];
            if x > hi {
                hi = x;
            }
            if x < lo {
                lo = x;
            }
        }
        if g.n_events > 0 {
            out.add_group(g.n_events as f64, hi - lo);
        }
    }
    out
}

/// All coordinates, O(np) — fanned across feature blocks for problems
/// big enough to amortize the thread spawn (each coordinate is
/// independent, so the output is identical either way).
pub fn all_lipschitz(problem: &CoxProblem) -> Vec<LipschitzPair> {
    let p = problem.p();
    if problem.n().saturating_mul(p) < (1 << 16) {
        return (0..p).map(|l| coord_lipschitz(problem, l)).collect();
    }
    crate::util::parallel::par_map_indices(p, |l| coord_lipschitz(problem, l))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cox::derivatives::coord_derivs;
    use crate::cox::state::CoxState;
    use crate::data::SurvivalDataset;
    use crate::linalg::Matrix;
    use crate::util::proptest::{check, gen};
    use crate::util::rng::Rng;

    fn random_problem(n: usize, p: usize, seed: u64) -> CoxProblem {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f64>> =
            (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let time: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.5, 9.5)).collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.6)).collect();
        CoxProblem::new(&SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "r"))
    }

    /// Property: for any β, 0 ≤ d2 ≤ L2 and |d3| ≤ L3 (Theorem 3.4).
    #[test]
    fn bounds_hold_for_random_beta() {
        check(
            "lipschitz-bounds",
            7,
            40,
            |r| {
                let seed = r.next_u64();
                let beta = gen::uniform_vec(r, 3, -3.0, 3.0);
                (seed, beta)
            },
            |(seed, beta)| {
                let pr = random_problem(20, 3, *seed);
                let st = CoxState::from_beta(&pr, beta);
                for l in 0..3 {
                    let d = coord_derivs(&pr, &st, l);
                    let lc = coord_lipschitz(&pr, l);
                    if d.d2 < -1e-9 || d.d2 > lc.l2 + 1e-9 {
                        return Err(format!("d2={} outside [0, {}]", d.d2, lc.l2));
                    }
                    if d.d3.abs() > lc.l3 + 1e-9 {
                        return Err(format!("|d3|={} > L3={}", d.d3.abs(), lc.l3));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn constant_column_has_zero_constants() {
        let x = Matrix::from_columns(&[vec![2.5; 6], vec![0.0, 1.0, 0.0, 1.0, 0.0, 1.0]]);
        let ds = SurvivalDataset::new(
            x,
            vec![6.0, 5.0, 4.0, 3.0, 2.0, 1.0],
            vec![true; 6],
            "c",
        );
        let pr = CoxProblem::new(&ds);
        let lc = coord_lipschitz(&pr, 0);
        assert_eq!(lc.l2, 0.0);
        assert_eq!(lc.l3, 0.0);
        assert!(coord_lipschitz(&pr, 1).l2 > 0.0);
    }

    #[test]
    fn binary_column_closed_form() {
        // Binary column: range in risk set i is 1 once both levels are in
        // the prefix, so L2 = ¼ · (#events with mixed prefix).
        let x = Matrix::from_columns(&[vec![1.0, 0.0, 1.0, 0.0]]);
        let ds = SurvivalDataset::new(x, vec![4.0, 3.0, 2.0, 1.0], vec![true; 4], "b");
        let pr = CoxProblem::new(&ds);
        let lc = coord_lipschitz(&pr, 0);
        // Events at prefix sizes 1..4; mixed from the 2nd on → 3 events.
        assert!((lc.l2 - 3.0 * 0.25).abs() < 1e-12);
        assert!((lc.l3 - 3.0 * INV_6_SQRT3).abs() < 1e-12);
    }

    #[test]
    fn popoviciu_tightness_example() {
        // Appendix A.3's tight example: P[a]=P[b]=¼, P[mid]=½ attains the
        // third-central-moment bound |b−a|³/(6√3). Check our constant.
        let a = -1.0_f64;
        let b = 1.0_f64;
        let probs = [0.25, 0.5, 0.25];
        let xs = [a, (a + b) / 2.0, b];
        let m3 = crate::cox::moments::central_moment(&probs, &xs, 3);
        // This symmetric example has zero skew; the extremal distribution
        // from the proof is asymmetric: P[a]=2/3 at variance (b−a)²/6.
        assert!(m3.abs() < 1e-12);
        // Extremal: variance V=(b−a)²/6 with two-point mass p at a:
        // p(1−p)(b−a)² = V ⇒ p = (3±√3)/6; skew = (b−a)³ p(1−p)(1−2p).
        let range = b - a;
        let p = (3.0 - 3.0_f64.sqrt()) / 6.0;
        let skew = range.powi(3) * p * (1.0 - p) * (1.0 - 2.0 * p);
        assert!((skew - INV_6_SQRT3 * range.powi(3)).abs() < 1e-12);
    }

    #[test]
    fn all_lipschitz_matches_each() {
        let pr = random_problem(25, 4, 3);
        let all = all_lipschitz(&pr);
        for l in 0..4 {
            assert_eq!(all[l], coord_lipschitz(&pr, l));
        }
    }
}
