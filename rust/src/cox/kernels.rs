//! Portable SIMD kernels: hand-unrolled multi-accumulator lanes for the
//! Cox derivative hot path, in std-only Rust.
//!
//! Design contract (shared with the scalar reference kernels in
//! [`super::derivatives`]):
//!
//! * **Per-column accumulation order is never changed.** The batched
//!   multi-column kernel interleaves [`LANES`] columns per row so the
//!   shared weight column is loaded once per lane group and each column
//!   owns an independent accumulator chain (instruction-level
//!   parallelism the latency-bound scalar chain cannot reach) — but
//!   within a column the operation sequence is exactly the scalar cached
//!   kernel's, so batched results are **bitwise** equal across backends
//!   and thread counts.
//! * **Reductions reassociate only inside tie groups** of at least
//!   [`LANE_MIN`] samples (fixed lane count, fixed tree fold). On
//!   continuous (untied) data every group is a singleton, the scalar
//!   path runs, and single-column results are bitwise equal too; with
//!   heavy ties the reassociated sums agree to ≤1e-12 relative.
//! * **Blocking depends on problem shape only** (row-tile cuts land on
//!   tie-group boundaries, sized by `block_rows`), never on the thread
//!   count, preserving the crate-wide bitwise thread-invariance
//!   contract.

use super::problem::TieGroup;
use crate::linalg::Matrix;
use crate::util::compute::LANES;

/// Minimum slice length before a lane-unrolled reduction pays (and the
/// only place a reassociated sum is allowed to replace the scalar one).
pub(crate) const LANE_MIN: usize = 8;

/// Fixed tree fold of the lane accumulators — one deterministic order,
/// independent of data or thread count.
#[inline]
fn fold_lanes(acc: [f64; LANES]) -> f64 {
    (acc[0] + acc[2]) + (acc[1] + acc[3])
}

/// Σ w over a slice with [`LANES`] independent accumulator chains.
#[inline]
pub(crate) fn sum1(w: &[f64]) -> f64 {
    let n = w.len();
    let mut acc = [0.0_f64; LANES];
    let whole = n - n % LANES;
    let mut k = 0;
    while k < whole {
        for (j, a) in acc.iter_mut().enumerate() {
            *a += w[k + j];
        }
        k += LANES;
    }
    let mut s = fold_lanes(acc);
    for &v in &w[whole..] {
        s += v;
    }
    s
}

/// (Σ w, Σ w·x) over a slice pair, lane-unrolled.
#[inline]
pub(crate) fn sum2(w: &[f64], x: &[f64]) -> (f64, f64) {
    let n = w.len();
    debug_assert_eq!(n, x.len());
    let mut a0 = [0.0_f64; LANES];
    let mut a1 = [0.0_f64; LANES];
    let whole = n - n % LANES;
    let mut k = 0;
    while k < whole {
        for j in 0..LANES {
            let wk = w[k + j];
            a0[j] += wk;
            a1[j] += wk * x[k + j];
        }
        k += LANES;
    }
    let mut s0 = fold_lanes(a0);
    let mut s1 = fold_lanes(a1);
    for k in whole..n {
        let wk = w[k];
        s0 += wk;
        s1 += wk * x[k];
    }
    (s0, s1)
}

/// (Σ w, Σ w·x, Σ w·x²) over a slice pair, lane-unrolled.
#[inline]
pub(crate) fn sum3(w: &[f64], x: &[f64]) -> (f64, f64, f64) {
    let n = w.len();
    debug_assert_eq!(n, x.len());
    let mut a0 = [0.0_f64; LANES];
    let mut a1 = [0.0_f64; LANES];
    let mut a2 = [0.0_f64; LANES];
    let whole = n - n % LANES;
    let mut k = 0;
    while k < whole {
        for j in 0..LANES {
            let wk = w[k + j];
            let xv = x[k + j];
            let wx = wk * xv;
            a0[j] += wk;
            a1[j] += wx;
            a2[j] += wx * xv;
        }
        k += LANES;
    }
    let mut s0 = fold_lanes(a0);
    let mut s1 = fold_lanes(a1);
    let mut s2 = fold_lanes(a2);
    for k in whole..n {
        let wk = w[k];
        let xv = x[k];
        s0 += wk;
        s1 += wk * xv;
        s2 += wk * xv * xv;
    }
    (s0, s1, s2)
}

/// (Σ w, Σ w·x, Σ w·x², Σ w·x³) over a slice pair, lane-unrolled.
#[inline]
pub(crate) fn sum4(w: &[f64], x: &[f64]) -> (f64, f64, f64, f64) {
    let n = w.len();
    debug_assert_eq!(n, x.len());
    let mut a0 = [0.0_f64; LANES];
    let mut a1 = [0.0_f64; LANES];
    let mut a2 = [0.0_f64; LANES];
    let mut a3 = [0.0_f64; LANES];
    let whole = n - n % LANES;
    let mut k = 0;
    while k < whole {
        for j in 0..LANES {
            let wk = w[k + j];
            let xv = x[k + j];
            let wx = wk * xv;
            a0[j] += wk;
            a1[j] += wx;
            a2[j] += wx * xv;
            a3[j] += wx * xv * xv;
        }
        k += LANES;
    }
    let mut s0 = fold_lanes(a0);
    let mut s1 = fold_lanes(a1);
    let mut s2 = fold_lanes(a2);
    let mut s3 = fold_lanes(a3);
    for k in whole..n {
        let wk = w[k];
        let xv = x[k];
        let wx = wk * xv;
        s0 += wk;
        s1 += wx;
        s2 += wx * xv;
        s3 += wx * xv * xv;
    }
    (s0, s1, s2, s3)
}

/// Lane-unrolled `Σ_k w_k·x_k·suffix_a[group_of[k]]` — the cached d1
/// pass of the ℓ1-sparse CD hot loop. Reassociates across rows (this
/// reduction has no per-group emission to respect), so callers compare
/// it to the scalar pass at ≤1e-12, not bitwise.
pub(crate) fn weighted_suffix_dot(
    w: &[f64],
    x: &[f64],
    group_of: &[usize],
    suffix_a: &[f64],
) -> f64 {
    let n = w.len();
    let mut acc = [0.0_f64; LANES];
    let whole = n - n % LANES;
    let mut k = 0;
    while k < whole {
        for (j, a) in acc.iter_mut().enumerate() {
            let i = k + j;
            *a += w[i] * x[i] * suffix_a[group_of[i]];
        }
        k += LANES;
    }
    let mut s = fold_lanes(acc);
    for k in whole..n {
        s += w[k] * x[k] * suffix_a[group_of[k]];
    }
    s
}

/// The scalar per-column cached (d1, d2) kernel — one source of truth
/// shared by `Workspace::coord_d1_d2_from_cache`, the scalar batched
/// pass, and the remainder columns of the SIMD batched pass. Per-column
/// operation order here IS the bitwise contract the lane kernel below
/// reproduces.
pub(crate) fn cached_col_d1_d2(
    groups: &[TieGroup],
    w: &[f64],
    col: &[f64],
    xt_delta_l: f64,
    group_inv_s0: &[f64],
    group_weight: &[f64],
) -> (f64, f64) {
    let (mut s1, mut s2) = (0.0_f64, 0.0_f64);
    let (mut a1, mut a2) = (0.0_f64, 0.0_f64);
    for (gi, g) in groups.iter().enumerate() {
        for k in g.start..g.end {
            let wx = w[k] * col[k];
            s1 += wx;
            s2 += wx * col[k];
        }
        let gw = group_weight[gi];
        if gw > 0.0 {
            // gw·s1 = ne·m1 and gw·s2 − (gw·s1)·m1 = ne·(m2 − m1²).
            let m1 = s1 * group_inv_s0[gi];
            let t1 = gw * s1;
            a1 += t1;
            a2 += gw * s2 - t1 * m1;
        }
    }
    (a1 - xt_delta_l, a2)
}

/// Row-tile cuts (as tie-group index boundaries) for the batched SIMD
/// kernel: consecutive groups are folded into one tile until it holds at
/// least `block_rows` samples. Cutting on group boundaries keeps the
/// per-column accumulator state tile-independent; sizing from shape
/// alone keeps results thread-count invariant.
pub(crate) fn row_tiles(groups: &[TieGroup], block_rows: usize) -> Vec<usize> {
    let mut cuts = Vec::with_capacity(4);
    cuts.push(0);
    let mut rows = 0usize;
    for (gi, g) in groups.iter().enumerate() {
        rows += g.end - g.start;
        if rows >= block_rows && gi + 1 < groups.len() {
            cuts.push(gi + 1);
            rows = 0;
        }
    }
    cuts.push(groups.len());
    cuts
}

/// Batched (d1, d2) over columns `lo..hi` with the multi-column
/// interleaved lane kernel: [`LANES`] columns advance together per row,
/// the shared weight column is read once per lane group per tile (and
/// stays cache-hot across the lane groups of a tile), and each column
/// keeps an independent accumulator chain. Per-column operation order
/// matches [`cached_col_d1_d2`] exactly — results are bitwise equal to
/// the scalar backend. `tile_cuts` comes from [`row_tiles`]; `d1`/`d2`
/// have length `hi - lo`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn batched_d1_d2_block(
    groups: &[TieGroup],
    w: &[f64],
    x: &Matrix,
    xt_delta: &[f64],
    group_inv_s0: &[f64],
    group_weight: &[f64],
    tile_cuts: &[usize],
    lo: usize,
    hi: usize,
    d1: &mut [f64],
    d2: &mut [f64],
) {
    let ncols = hi - lo;
    debug_assert_eq!(d1.len(), ncols);
    debug_assert_eq!(d2.len(), ncols);
    let full = ncols - ncols % LANES;
    // Per-column accumulator state persists across row tiles.
    let mut s1v = vec![0.0_f64; full];
    let mut s2v = vec![0.0_f64; full];
    let mut a1v = vec![0.0_f64; full];
    let mut a2v = vec![0.0_f64; full];
    let ntiles = tile_cuts.len().saturating_sub(1);
    for t in 0..ntiles {
        let (g_lo, g_hi) = (tile_cuts[t], tile_cuts[t + 1]);
        let mut c0 = 0;
        while c0 < full {
            let cols: [&[f64]; LANES] = std::array::from_fn(|j| x.col(lo + c0 + j));
            let mut s1 = [0.0_f64; LANES];
            let mut s2 = [0.0_f64; LANES];
            let mut a1 = [0.0_f64; LANES];
            let mut a2 = [0.0_f64; LANES];
            s1.copy_from_slice(&s1v[c0..c0 + LANES]);
            s2.copy_from_slice(&s2v[c0..c0 + LANES]);
            a1.copy_from_slice(&a1v[c0..c0 + LANES]);
            a2.copy_from_slice(&a2v[c0..c0 + LANES]);
            for gi in g_lo..g_hi {
                let g = &groups[gi];
                for k in g.start..g.end {
                    let wk = w[k];
                    for j in 0..LANES {
                        let xv = cols[j][k];
                        let wx = wk * xv;
                        s1[j] += wx;
                        s2[j] += wx * xv;
                    }
                }
                let gw = group_weight[gi];
                if gw > 0.0 {
                    let inv = group_inv_s0[gi];
                    for j in 0..LANES {
                        let m1 = s1[j] * inv;
                        let t1 = gw * s1[j];
                        a1[j] += t1;
                        a2[j] += gw * s2[j] - t1 * m1;
                    }
                }
            }
            s1v[c0..c0 + LANES].copy_from_slice(&s1);
            s2v[c0..c0 + LANES].copy_from_slice(&s2);
            a1v[c0..c0 + LANES].copy_from_slice(&a1);
            a2v[c0..c0 + LANES].copy_from_slice(&a2);
            c0 += LANES;
        }
    }
    for c in 0..full {
        d1[c] = a1v[c] - xt_delta[lo + c];
        d2[c] = a2v[c];
    }
    // Remainder columns (< LANES of them): the scalar cached kernel.
    for c in full..ncols {
        let (a, b) = cached_col_d1_d2(
            groups,
            w,
            x.col(lo + c),
            xt_delta[lo + c],
            group_inv_s0,
            group_weight,
        );
        d1[c] = a;
        d2[c] = b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_sums_match_sequential_reference() {
        let n = 37; // exercises whole chunks + tail
        let w: Vec<f64> = (0..n).map(|i| 0.25 + (i as f64) * 0.013).collect();
        let x: Vec<f64> = (0..n).map(|i| ((i * 7 % 11) as f64) - 5.0).collect();
        let r0: f64 = w.iter().sum();
        let r1: f64 = w.iter().zip(&x).map(|(&a, &b)| a * b).sum();
        let r2: f64 = w.iter().zip(&x).map(|(&a, &b)| a * b * b).sum();
        let r3: f64 = w.iter().zip(&x).map(|(&a, &b)| a * b * b * b).sum();
        assert!((sum1(&w) - r0).abs() <= 1e-12 * r0.abs());
        let (s0, s1) = sum2(&w, &x);
        assert!((s0 - r0).abs() <= 1e-12 * r0.abs());
        assert!((s1 - r1).abs() <= 1e-12 * r1.abs().max(1.0));
        let (t0, t1, t2) = sum3(&w, &x);
        assert!((t0 - r0).abs() <= 1e-12 * r0.abs());
        assert!((t1 - r1).abs() <= 1e-12 * r1.abs().max(1.0));
        assert!((t2 - r2).abs() <= 1e-12 * r2.abs().max(1.0));
        let (u0, u1, u2, u3) = sum4(&w, &x);
        assert!((u0 - r0).abs() <= 1e-12 * r0.abs());
        assert!((u1 - r1).abs() <= 1e-12 * r1.abs().max(1.0));
        assert!((u2 - r2).abs() <= 1e-12 * r2.abs().max(1.0));
        assert!((u3 - r3).abs() <= 1e-12 * r3.abs().max(1.0));
    }

    #[test]
    fn tiles_cover_all_groups_exactly_once() {
        let groups: Vec<TieGroup> = (0..10)
            .map(|i| TieGroup { start: i * 5, end: i * 5 + 5, n_events: 1 })
            .collect();
        for block_rows in [1usize, 7, 12, 25, 1000] {
            let cuts = row_tiles(&groups, block_rows);
            assert_eq!(cuts[0], 0);
            assert_eq!(*cuts.last().unwrap(), groups.len());
            for pair in cuts.windows(2) {
                assert!(pair[0] < pair[1], "cuts must strictly increase: {cuts:?}");
            }
        }
        // Empty problems tile to a single empty span.
        let cuts = row_tiles(&[], 1024);
        assert_eq!(cuts, vec![0, 0]);
    }
}
