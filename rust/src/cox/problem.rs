//! Preprocessed Cox problem: samples sorted by descending time with tie
//! groups, so risk sets are prefixes.

use crate::data::SurvivalDataset;
use crate::error::{FastSurvivalError, Result};
use crate::linalg::Matrix;

/// A tie group: positions `[start, end)` in sorted order share one time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TieGroup {
    pub start: usize,
    pub end: usize,
    /// Number of events (δ=1) in this group.
    pub n_events: usize,
}

/// Dataset re-sorted by descending time; the immutable half of a fit.
#[derive(Clone, Debug)]
pub struct CoxProblem {
    /// Features in sorted order, column-major (n×p).
    pub x: Matrix,
    /// Observation times, descending.
    pub time: Vec<f64>,
    /// Event indicators in sorted order (1.0 / 0.0 for arithmetic use).
    pub delta: Vec<f64>,
    /// Tie groups in sorted order. Risk set of any sample in group g is
    /// the prefix `0..groups[g].end`.
    pub groups: Vec<TieGroup>,
    /// For each sorted position, its group index.
    pub group_of: Vec<usize>,
    /// Precomputed constant term of the gradient: `(X^T δ)_l` (Eq. 7's
    /// second sum) — independent of β.
    pub xt_delta: Vec<f64>,
    /// Map sorted position -> original dataset index.
    pub order: Vec<usize>,
    /// Total number of events.
    pub n_events: usize,
    /// Per-column flag: values all in {0, 1}. The Sec-4.2 binarized
    /// datasets are entirely binary, enabling a shared exp(Δ) factor on
    /// the coordinate-update hot path (see `CoxState::update_coord`).
    pub col_binary: Vec<bool>,
}

/// The canonical sample order for Cox fitting: descending observation
/// time, stable on ties by original index. Both [`CoxProblem::try_new`]
/// and the out-of-core store writer sort through this one function, so a
/// pre-sorted `.fsds` store and an in-memory problem built from the same
/// data agree row for row.
///
/// Precondition: every time is finite (validated by both callers before
/// sorting, which makes the comparison total).
pub fn descending_time_order(time: &[f64]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..time.len()).collect();
    order.sort_by(|&a, &b| {
        time[b]
            .partial_cmp(&time[a])
            .expect("times validated finite")
            .then(a.cmp(&b))
    });
    order
}

/// Tie groups over descending-sorted times (`delta` in the same order,
/// 1.0 = event). Returns `(groups, group_of)`. Shared by
/// [`CoxProblem::try_new`] and the chunked store reader so both derive
/// the identical risk-set structure from identical sorted times.
pub fn build_tie_groups(time: &[f64], delta: &[f64]) -> (Vec<TieGroup>, Vec<usize>) {
    let n = time.len();
    let mut groups = Vec::new();
    let mut group_of = vec![0usize; n];
    let mut start = 0;
    while start < n {
        let mut end = start + 1;
        while end < n && time[end] == time[start] {
            end += 1;
        }
        let n_events = delta[start..end].iter().map(|&d| d as usize).sum();
        let g = groups.len();
        for item in group_of.iter_mut().take(end).skip(start) {
            *item = g;
        }
        groups.push(TieGroup { start, end, n_events });
        start = end;
    }
    (groups, group_of)
}

impl CoxProblem {
    /// Build from a dataset (copies + sorts; O(n log n + np)), panicking
    /// on invalid input. Trusted internal callers only; fallible paths
    /// (the `CoxFit` builder, the CLI) go through [`CoxProblem::try_new`].
    pub fn new(ds: &SurvivalDataset) -> Self {
        Self::try_new(ds).unwrap_or_else(|e| panic!("CoxProblem::new: {e}"))
    }

    /// Build from a dataset, validating it first: a typed
    /// [`FastSurvivalError::InvalidData`] replaces the old `assert!` /
    /// `expect("NaN time")` panics.
    pub fn try_new(ds: &SurvivalDataset) -> Result<Self> {
        let n = ds.n();
        if n == 0 {
            return Err(FastSurvivalError::InvalidData("empty dataset (n = 0)".into()));
        }
        if let Some(i) = ds.time.iter().position(|t| !t.is_finite()) {
            return Err(FastSurvivalError::InvalidData(format!(
                "non-finite observation time at sample {i}: {}",
                ds.time[i]
            )));
        }
        if let Some(k) = ds.x.data.iter().position(|v| !v.is_finite()) {
            return Err(FastSurvivalError::InvalidData(format!(
                "non-finite feature value (column {}, row {})",
                k / n.max(1),
                k % n.max(1)
            )));
        }
        // Descending time; stable on ties by original index for
        // determinism. Finiteness was validated above, so the comparison
        // is total.
        let order = descending_time_order(&ds.time);

        let x = ds.x.select_rows(&order);
        let time: Vec<f64> = order.iter().map(|&i| ds.time[i]).collect();
        let delta: Vec<f64> = order.iter().map(|&i| if ds.event[i] { 1.0 } else { 0.0 }).collect();

        // Tie groups over equal times.
        let (groups, group_of) = build_tie_groups(&time, &delta);

        let xt_delta = x.tr_matvec(&delta);
        let n_events = delta.iter().map(|&d| d as usize).sum();
        let col_binary = (0..x.cols)
            .map(|c| x.col(c).iter().all(|&v| v == 0.0 || v == 1.0))
            .collect();

        Ok(CoxProblem { x, time, delta, groups, group_of, xt_delta, order, n_events, col_binary })
    }

    pub fn n(&self) -> usize {
        self.x.rows
    }

    pub fn p(&self) -> usize {
        self.x.cols
    }

    /// Risk-set end (exclusive) for sorted position i: all of R_i is the
    /// prefix `0..risk_end(i)`.
    #[inline]
    pub fn risk_end(&self, i: usize) -> usize {
        self.groups[self.group_of[i]].end
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SurvivalDataset;
    use crate::linalg::Matrix;

    fn ds_with_ties() -> SurvivalDataset {
        let x = Matrix::from_columns(&[vec![1.0, 2.0, 3.0, 4.0, 5.0]]);
        SurvivalDataset::new(
            x,
            vec![2.0, 5.0, 2.0, 7.0, 1.0],
            vec![true, true, false, true, true],
            "ties",
        )
    }

    #[test]
    fn sorted_descending_with_groups() {
        let p = CoxProblem::new(&ds_with_ties());
        assert_eq!(p.time, vec![7.0, 5.0, 2.0, 2.0, 1.0]);
        assert_eq!(p.groups.len(), 4);
        assert_eq!(p.groups[2], TieGroup { start: 2, end: 4, n_events: 1 });
        // Risk set of either tied sample covers both.
        assert_eq!(p.risk_end(2), 4);
        assert_eq!(p.risk_end(3), 4);
        assert_eq!(p.risk_end(0), 1);
    }

    #[test]
    fn order_maps_back() {
        let ds = ds_with_ties();
        let p = CoxProblem::new(&ds);
        for (pos, &orig) in p.order.iter().enumerate() {
            assert_eq!(p.time[pos], ds.time[orig]);
            assert_eq!(p.x.get(pos, 0), ds.x.get(orig, 0));
        }
    }

    #[test]
    fn xt_delta_matches_manual() {
        let ds = ds_with_ties();
        let p = CoxProblem::new(&ds);
        // events at original idx 0,1,3,4 → x values 1,2,4,5 → sum 12
        assert_eq!(p.xt_delta, vec![12.0]);
        assert_eq!(p.n_events, 4);
    }

    #[test]
    fn stable_tie_order() {
        let ds = ds_with_ties();
        let p = CoxProblem::new(&ds);
        // Tied at t=2.0: original indices 0 then 2.
        assert_eq!(&p.order[2..4], &[0, 2]);
    }

    #[test]
    fn try_new_rejects_invalid_data_with_typed_errors() {
        use crate::error::FastSurvivalError;
        // Empty dataset.
        let empty = SurvivalDataset::new(Matrix::zeros(0, 1), vec![], vec![], "empty");
        assert!(matches!(
            CoxProblem::try_new(&empty),
            Err(FastSurvivalError::InvalidData(_))
        ));
        // NaN time.
        let x = Matrix::from_columns(&[vec![1.0, 2.0]]);
        let nan_t = SurvivalDataset::new(x, vec![1.0, f64::NAN], vec![true, true], "nan");
        let err = CoxProblem::try_new(&nan_t).unwrap_err();
        assert!(err.to_string().contains("sample 1"), "got: {err}");
        // Non-finite feature.
        let x = Matrix::from_columns(&[vec![1.0, f64::INFINITY]]);
        let inf_x = SurvivalDataset::new(x, vec![2.0, 1.0], vec![true, true], "inf");
        assert!(matches!(
            CoxProblem::try_new(&inf_x),
            Err(FastSurvivalError::InvalidData(_))
        ));
        // Valid data still passes.
        assert!(CoxProblem::try_new(&ds_with_ties()).is_ok());
    }
}
