//! Time-varying covariates (Andersen–Gill counting-process format) —
//! another extension the paper lists ("CPH models with time-varying
//! features \[16\]").
//!
//! Each record is an interval (start, stop] with fixed covariates; a
//! subject contributes several records as its covariates change. The
//! risk set at event time t is `{j : start_j < t <= stop_j}`, which is
//! *not* a prefix of any single order — but it **is a difference of two
//! prefixes**: records with `stop >= t` (prefix in descending-stop
//! order) minus records with `start >= t` (prefix in descending-start
//! order). The paper's O(n) cumulative-moment blessing therefore
//! survives intact: every power sum S_r(t) is one subtraction of two
//! running sums, and Theorem 3.1's central-moment formulas apply
//! unchanged.

use crate::linalg::Matrix;
use crate::optim::prox::{quad_l1_step, quad_step};
use crate::optim::{Objective, Trace};
use std::time::Instant;

/// Counting-process Cox problem.
pub struct TvCoxProblem {
    /// Record features (n_records × p).
    pub x: Matrix,
    pub start: Vec<f64>,
    pub stop: Vec<f64>,
    /// Event indicator: the subject fails at `stop` of this record.
    pub event: Vec<bool>,
    /// Record indices sorted by descending stop (ties: stable).
    by_stop: Vec<usize>,
    /// Record indices sorted by descending start.
    by_start: Vec<usize>,
    /// Distinct event times, descending, with their event-record lists.
    event_times: Vec<(f64, Vec<usize>)>,
    /// Σ_events x_l (constant gradient term), per coordinate.
    xt_delta: Vec<f64>,
}

impl TvCoxProblem {
    pub fn new(x: Matrix, start: Vec<f64>, stop: Vec<f64>, event: Vec<bool>) -> Self {
        let n = x.rows;
        assert_eq!(start.len(), n);
        assert_eq!(stop.len(), n);
        assert_eq!(event.len(), n);
        for i in 0..n {
            assert!(start[i] < stop[i], "record {i}: start must be < stop");
        }
        let mut by_stop: Vec<usize> = (0..n).collect();
        by_stop.sort_by(|&a, &b| stop[b].partial_cmp(&stop[a]).unwrap().then(a.cmp(&b)));
        let mut by_start: Vec<usize> = (0..n).collect();
        by_start.sort_by(|&a, &b| start[b].partial_cmp(&start[a]).unwrap().then(a.cmp(&b)));

        // Distinct event times, descending (Breslow ties share risk sets).
        let mut times: Vec<(f64, Vec<usize>)> = Vec::new();
        let mut ev: Vec<usize> = (0..n).filter(|&i| event[i]).collect();
        ev.sort_by(|&a, &b| stop[b].partial_cmp(&stop[a]).unwrap());
        for i in ev {
            match times.last_mut() {
                Some((t, list)) if *t == stop[i] => list.push(i),
                _ => times.push((stop[i], vec![i])),
            }
        }

        let xt_delta = (0..x.cols)
            .map(|l| (0..n).filter(|&i| event[i]).map(|i| x.get(i, l)).sum())
            .collect();

        TvCoxProblem { x, start, stop, event, by_stop, by_start, event_times: times, xt_delta }
    }

    pub fn n_records(&self) -> usize {
        self.x.rows
    }

    pub fn p(&self) -> usize {
        self.x.cols
    }

    /// O(n) fused pass computing loss contribution, d1, and d2 for one
    /// coordinate at weights `w = exp(η)` (η = record score).
    ///
    /// Walking event times downward, two pointers admit records into the
    /// "stop-prefix" sums (stop >= t) and the "start-prefix" sums
    /// (start >= t); risk-set sums are their differences.
    pub fn coord_pass(&self, w: &[f64], l: usize) -> (f64, f64) {
        let col = self.x.col(l);
        let (mut a0, mut a1, mut a2) = (0.0_f64, 0.0_f64, 0.0_f64); // stop-prefix
        let (mut b0, mut b1, mut b2) = (0.0_f64, 0.0_f64, 0.0_f64); // start-prefix
        let (mut ps, mut pt) = (0usize, 0usize);
        let (mut d1, mut d2) = (0.0, 0.0);
        for (t, events) in &self.event_times {
            while ps < self.by_stop.len() && self.stop[self.by_stop[ps]] >= *t {
                let j = self.by_stop[ps];
                let wj = w[j];
                a0 += wj;
                a1 += wj * col[j];
                a2 += wj * col[j] * col[j];
                ps += 1;
            }
            while pt < self.by_start.len() && self.start[self.by_start[pt]] >= *t {
                let j = self.by_start[pt];
                let wj = w[j];
                b0 += wj;
                b1 += wj * col[j];
                b2 += wj * col[j] * col[j];
                pt += 1;
            }
            let s0 = a0 - b0;
            if s0 <= 0.0 {
                continue;
            }
            let m1 = (a1 - b1) / s0;
            let m2 = (a2 - b2) / s0;
            let ne = events.len() as f64;
            d1 += ne * m1;
            d2 += ne * (m2 - m1 * m1).max(0.0);
        }
        (d1 - self.xt_delta[l], d2)
    }

    /// Negative log partial likelihood at record weights w = exp(η − m).
    pub fn loss(&self, w: &[f64], eta: &[f64], shift: f64) -> f64 {
        let mut a0 = 0.0_f64;
        let mut b0 = 0.0_f64;
        let (mut ps, mut pt) = (0usize, 0usize);
        let mut total = 0.0;
        for (t, events) in &self.event_times {
            while ps < self.by_stop.len() && self.stop[self.by_stop[ps]] >= *t {
                a0 += w[self.by_stop[ps]];
                ps += 1;
            }
            while pt < self.by_start.len() && self.start[self.by_start[pt]] >= *t {
                b0 += w[self.by_start[pt]];
                pt += 1;
            }
            let s0 = a0 - b0;
            if s0 <= 0.0 {
                continue;
            }
            for &i in events {
                total += s0.ln() + shift - eta[i];
            }
        }
        total
    }

    /// Conservative per-coordinate Lipschitz constant: Popoviciu with the
    /// *global* column range, which bounds every risk-set range (risk
    /// sets shed members, so prefix extrema no longer apply).
    pub fn coord_lipschitz_l2(&self, l: usize) -> f64 {
        let col = self.x.col(l);
        let hi = col.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lo = col.iter().cloned().fold(f64::INFINITY, f64::min);
        let range = (hi - lo).max(0.0);
        let n_events: f64 = self.event.iter().filter(|&&e| e).count() as f64;
        0.25 * range * range * n_events
    }

    /// Quadratic-surrogate CD fit (monotone, no line search).
    pub fn fit(&self, obj: Objective, max_sweeps: usize, tol: f64) -> (Vec<f64>, Trace) {
        let n = self.n_records();
        let p = self.p();
        let mut beta = vec![0.0_f64; p];
        let mut eta = vec![0.0_f64; n];
        let mut w = vec![1.0_f64; n];
        let mut shift = 0.0_f64;
        let lip: Vec<f64> = (0..p).map(|l| self.coord_lipschitz_l2(l)).collect();
        let mut trace = Trace::default();
        let start_t = Instant::now();
        let mut prev = f64::INFINITY;
        for sweep in 0..max_sweeps {
            for l in 0..p {
                let b = lip[l] + 2.0 * obj.l2;
                if b <= 0.0 {
                    continue;
                }
                let (d1, _) = self.coord_pass(&w, l);
                let a = d1 + 2.0 * obj.l2 * beta[l];
                let delta = if obj.l1 > 0.0 {
                    quad_l1_step(a, b, beta[l], obj.l1)
                } else {
                    quad_step(a, b)
                };
                if delta != 0.0 {
                    beta[l] += delta;
                    let col = self.x.col(l);
                    let mut max_eta = f64::NEG_INFINITY;
                    for k in 0..n {
                        eta[k] += delta * col[k];
                        max_eta = max_eta.max(eta[k]);
                    }
                    if (max_eta - shift).abs() > 30.0 {
                        shift = max_eta;
                    }
                    for k in 0..n {
                        w[k] = (eta[k] - shift).exp();
                    }
                }
            }
            let val = self.loss(&w, &eta, shift)
                + obj.l1 * beta.iter().map(|b| b.abs()).sum::<f64>()
                + obj.l2 * beta.iter().map(|b| b * b).sum::<f64>();
            trace.push(sweep, start_t, val);
            if prev.is_finite() && (prev - val).abs() < tol * (prev.abs() + 1.0) {
                trace.converged = true;
                break;
            }
            prev = val;
        }
        (beta, trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cox::{CoxProblem, CoxState};
    use crate::data::SurvivalDataset;
    use crate::util::rng::Rng;

    /// With all starts at -inf-ish (before every stop), the counting-
    /// process model reduces to the standard Cox model.
    fn standard_as_tv(n: usize, p: usize, seed: u64) -> (TvCoxProblem, CoxProblem) {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f64>> =
            (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let time: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.5, 9.5)).collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.7)).collect();
        let x = Matrix::from_columns(&cols);
        let tv = TvCoxProblem::new(
            x.clone(),
            vec![0.0; n],
            time.clone(),
            event.clone(),
        );
        let std = CoxProblem::new(&SurvivalDataset::new(x, time, event, "std"));
        (tv, std)
    }

    #[test]
    fn reduces_to_standard_cox_derivatives() {
        let (tv, std) = standard_as_tv(40, 3, 1);
        let mut rng = Rng::new(2);
        let beta: Vec<f64> = (0..3).map(|_| rng.normal() * 0.5).collect();
        let st = CoxState::from_beta(&std, &beta);
        // Map weights back to tv's record order (tv keeps input order).
        let eta_tv = tv.x.matvec(&beta);
        let m = eta_tv.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let w_tv: Vec<f64> = eta_tv.iter().map(|&e| (e - m).exp()).collect();
        for l in 0..3 {
            let (d1_tv, d2_tv) = tv.coord_pass(&w_tv, l);
            let (d1_s, d2_s) = crate::cox::derivatives::coord_d1_d2(&std, &st, l);
            assert!((d1_tv - d1_s).abs() < 1e-8, "d1 {d1_tv} vs {d1_s}");
            assert!((d2_tv - d2_s).abs() < 1e-8, "d2 {d2_tv} vs {d2_s}");
        }
        let loss_tv = tv.loss(&w_tv, &eta_tv, m);
        let loss_s = crate::cox::loss::loss(&std, &st);
        assert!((loss_tv - loss_s).abs() < 1e-8, "{loss_tv} vs {loss_s}");
    }

    #[test]
    fn d1_matches_finite_difference() {
        // A genuinely time-varying problem: subjects switch covariates.
        let mut rng = Rng::new(5);
        let n_subj = 25;
        let (mut xs, mut starts, mut stops, mut events) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        for _ in 0..n_subj {
            let t_switch = rng.uniform_range(0.5, 2.0);
            let t_end = t_switch + rng.uniform_range(0.5, 3.0);
            let x0 = rng.normal();
            let x1 = rng.normal();
            xs.push(vec![x0]);
            starts.push(0.0);
            stops.push(t_switch);
            events.push(false); // censored at switch (interval continues)
            xs.push(vec![x1]);
            starts.push(t_switch);
            stops.push(t_end);
            events.push(rng.bernoulli(0.8));
        }
        let cols = vec![xs.iter().map(|r| r[0]).collect::<Vec<f64>>()];
        let tv = TvCoxProblem::new(Matrix::from_columns(&cols), starts, stops, events);
        let beta = 0.3;
        let h = 1e-5;
        let lossat = |b: f64| {
            let eta: Vec<f64> = tv.x.col(0).iter().map(|&x| b * x).collect();
            let m = eta.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let w: Vec<f64> = eta.iter().map(|&e| (e - m).exp()).collect();
            tv.loss(&w, &eta, m)
        };
        let eta: Vec<f64> = tv.x.col(0).iter().map(|&x| beta * x).collect();
        let m = eta.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let w: Vec<f64> = eta.iter().map(|&e| (e - m).exp()).collect();
        let (d1, d2) = tv.coord_pass(&w, 0);
        let fd1 = (lossat(beta + h) - lossat(beta - h)) / (2.0 * h);
        let fd2 = (lossat(beta + h) - 2.0 * lossat(beta) + lossat(beta - h)) / (h * h);
        assert!((d1 - fd1).abs() < 1e-5, "d1 {d1} vs fd {fd1}");
        assert!((d2 - fd2).abs() < 1e-3, "d2 {d2} vs fd {fd2}");
    }

    #[test]
    fn fit_recovers_effect_and_descends() {
        // Strong positive effect with covariate switching mid-follow-up.
        let mut rng = Rng::new(7);
        let (mut xs, mut starts, mut stops, mut events) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        for _ in 0..400 {
            let x0 = rng.normal();
            let hazard = (1.2 * x0).exp();
            let t = rng.exponential() / hazard;
            let switch = 0.3;
            if t <= switch {
                xs.push(x0);
                starts.push(0.0);
                stops.push(t.max(1e-6));
                events.push(true);
            } else {
                xs.push(x0);
                starts.push(0.0);
                stops.push(switch);
                events.push(false);
                // After the switch the covariate jumps but keeps driving
                // hazard through the same β.
                let x1 = x0 + 0.5 * rng.normal();
                let t2 = switch + rng.exponential() / (1.2 * x1).exp();
                xs.push(x1);
                starts.push(switch);
                stops.push(t2);
                events.push(rng.bernoulli(0.85));
            }
        }
        let tv = TvCoxProblem::new(
            Matrix::from_columns(&[xs]),
            starts,
            stops,
            events,
        );
        let (beta, trace) = tv.fit(Objective { l1: 0.0, l2: 0.05 }, 200, 1e-10);
        assert!(trace.monotone(1e-9), "tv surrogate fit must be monotone");
        assert!(
            (beta[0] - 1.2).abs() < 0.25,
            "expected β≈1.2, got {}",
            beta[0]
        );
    }

    #[test]
    #[should_panic(expected = "start must be < stop")]
    fn rejects_bad_intervals() {
        TvCoxProblem::new(
            Matrix::from_columns(&[vec![1.0]]),
            vec![2.0],
            vec![1.0],
            vec![true],
        );
    }
}
