//! Negative log partial likelihood (Eq. 4), Breslow convention for ties.

use super::kernels;
use super::problem::{CoxProblem, TieGroup};
use super::state::CoxState;
use crate::util::compute::{default_backend, KernelBackend};

/// ℓ(β) = Σ_{i: δ_i=1} [ log Σ_{j∈R_i} e^{η_j} − η_i ].
///
/// One pass over tie groups: the risk set of every sample in a group is
/// the prefix ending at the group, so events in a group share one
/// log-denominator. O(n).
pub fn loss(problem: &CoxProblem, state: &CoxState) -> f64 {
    loss_for(problem, &state.eta, &state.w, state.shift)
}

/// Loss from explicit (η, w = exp(η − shift), shift) arrays — used by
/// line searches evaluating trial points without committing state.
pub fn loss_for(problem: &CoxProblem, eta: &[f64], w: &[f64], shift: f64) -> f64 {
    loss_for_parts(&problem.groups, &problem.delta, eta, w, shift)
}

/// [`loss_for`] from explicit risk-set parts (tie groups and the sorted
/// event indicators) instead of a [`CoxProblem`] — shared with the
/// out-of-core chunked driver, which holds groups/δ/η/w in memory but
/// never materializes the feature matrix.
pub fn loss_for_parts(
    groups: &[TieGroup],
    delta: &[f64],
    eta: &[f64],
    w: &[f64],
    shift: f64,
) -> f64 {
    loss_for_parts_b(default_backend(), groups, delta, eta, w, shift)
}

/// [`loss_for_parts`] with an explicit kernel backend. The SIMD arm
/// lane-sums the within-group weight partials for tie groups of ≥8
/// samples (reassociation ≤1e-12 before the log); singleton groups take
/// the scalar path bit for bit, so untied data is bitwise equal across
/// backends.
pub fn loss_for_parts_b(
    backend: KernelBackend,
    groups: &[TieGroup],
    delta: &[f64],
    eta: &[f64],
    w: &[f64],
    shift: f64,
) -> f64 {
    let mut s0 = 0.0_f64;
    let mut total = 0.0_f64;
    for g in groups {
        if backend == KernelBackend::Simd && g.end - g.start >= kernels::LANE_MIN {
            s0 += kernels::sum1(&w[g.start..g.end]);
        } else {
            for k in g.start..g.end {
                s0 += w[k];
            }
        }
        if g.n_events == 0 {
            continue;
        }
        let log_denom = s0.ln() + shift;
        total += g.n_events as f64 * log_denom;
        for i in g.start..g.end {
            if delta[i] == 1.0 {
                total -= eta[i];
            }
        }
    }
    total
}

/// Loss at a trial η (recomputes the stabilization internally).
pub fn loss_for_eta(problem: &CoxProblem, eta: &[f64]) -> f64 {
    let m = eta.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let m = if m.is_finite() { m } else { 0.0 };
    let w: Vec<f64> = eta.iter().map(|&e| (e - m).exp()).collect();
    loss_for(problem, eta, &w, m)
}

/// Loss plus separable penalties: λ1‖β‖₁ + λ2‖β‖₂².
pub fn penalized_loss(problem: &CoxProblem, state: &CoxState, l1: f64, l2: f64) -> f64 {
    let base = loss(problem, state);
    let pen1: f64 = state.beta.iter().map(|b| b.abs()).sum::<f64>() * l1;
    let pen2: f64 = state.beta.iter().map(|b| b * b).sum::<f64>() * l2;
    base + pen1 + pen2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cox::moments::naive_loss;
    use crate::data::SurvivalDataset;
    use crate::linalg::Matrix;
    use crate::util::rng::Rng;

    fn random_problem(n: usize, p: usize, seed: u64, ties: bool) -> (SurvivalDataset, CoxProblem) {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f64>> = (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let time: Vec<f64> = (0..n)
            .map(|_| {
                let t = rng.uniform_range(0.5, 9.5);
                if ties {
                    t.round()
                } else {
                    t
                }
            })
            .collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.7)).collect();
        let ds = SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "r");
        let pr = CoxProblem::new(&ds);
        (ds, pr)
    }

    #[test]
    fn matches_naive_no_ties() {
        for seed in 0..4 {
            let (_, pr) = random_problem(40, 3, seed, false);
            let mut rng = Rng::new(100 + seed);
            let beta: Vec<f64> = (0..3).map(|_| rng.normal() * 0.5).collect();
            let st = CoxState::from_beta(&pr, &beta);
            let fast = loss(&pr, &st);
            let naive = naive_loss(&pr, &st.eta);
            assert!((fast - naive).abs() < 1e-9, "{fast} vs {naive}");
        }
    }

    #[test]
    fn matches_naive_with_ties() {
        for seed in 0..4 {
            let (_, pr) = random_problem(50, 2, seed, true);
            let st = CoxState::from_beta(&pr, &[0.3, -0.7]);
            let fast = loss(&pr, &st);
            let naive = naive_loss(&pr, &st.eta);
            assert!((fast - naive).abs() < 1e-9, "{fast} vs {naive}");
        }
    }

    #[test]
    fn zero_beta_closed_form_no_ties() {
        // At β=0, each event i (risk set size m_i) contributes log(m_i).
        let (_, pr) = random_problem(30, 2, 9, false);
        let st = CoxState::zeros(&pr);
        let expect: f64 = (0..pr.n())
            .filter(|&i| pr.delta[i] == 1.0)
            .map(|i| (pr.risk_end(i) as f64).ln())
            .sum();
        assert!((loss(&pr, &st) - expect).abs() < 1e-9);
    }

    #[test]
    fn stable_under_huge_eta() {
        let (_, pr) = random_problem(30, 2, 11, false);
        let st = CoxState::from_beta(&pr, &[200.0, -150.0]);
        let l = loss(&pr, &st);
        assert!(l.is_finite(), "loss={l}");
    }

    #[test]
    fn penalized_adds_terms() {
        let (_, pr) = random_problem(20, 2, 13, false);
        let st = CoxState::from_beta(&pr, &[1.0, -2.0]);
        let base = loss(&pr, &st);
        let pl = penalized_loss(&pr, &st, 0.5, 0.25);
        assert!((pl - (base + 0.5 * 3.0 + 0.25 * 5.0)).abs() < 1e-12);
    }

    #[test]
    fn loss_backends_agree() {
        // Untied: bitwise. Tied (groups of ~5–8+ samples): ≤1e-12.
        for &ties in &[false, true] {
            let (_, pr) = random_problem(120, 3, 19, ties);
            let st = CoxState::from_beta(&pr, &[0.3, -0.2, 0.1]);
            let ls = loss_for_parts_b(
                KernelBackend::Scalar, &pr.groups, &pr.delta, &st.eta, &st.w, st.shift,
            );
            let lv = loss_for_parts_b(
                KernelBackend::Simd, &pr.groups, &pr.delta, &st.eta, &st.w, st.shift,
            );
            if ties {
                assert!((ls - lv).abs() <= 1e-12 * ls.abs().max(1.0), "{ls} vs {lv}");
            } else {
                assert_eq!(ls.to_bits(), lv.to_bits());
            }
        }
    }

    #[test]
    fn all_censored_loss_is_zero() {
        let x = Matrix::from_columns(&[vec![1.0, 2.0]]);
        let ds = SurvivalDataset::new(x, vec![2.0, 1.0], vec![false, false], "c");
        let pr = CoxProblem::new(&ds);
        let st = CoxState::from_beta(&pr, &[0.4]);
        assert_eq!(loss(&pr, &st), 0.0);
    }
}
