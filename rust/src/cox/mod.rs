//! The Cox proportional hazards core: loss, exact O(n) per-coordinate
//! derivatives (Theorem 3.1 / Corollary 3.3), explicit Lipschitz constants
//! (Theorem 3.4), and central-moment utilities (Lemma 3.2).
//!
//! Everything operates on a [`CoxProblem`] — the dataset re-sorted by
//! descending observation time so that every risk set
//! `R_i = {j : t_j >= t_i}` is a *prefix* of the sorted order (Breslow
//! convention for ties: all samples tied at `t_i` are in `R_i`). That
//! prefix structure is exactly what makes the paper's reverse-cumulative-
//! sum trick work.

pub mod derivatives;
pub mod kernels;
pub mod lipschitz;
pub mod loss;
pub mod moments;
pub mod problem;
pub mod state;
pub mod stratified;
pub mod time_varying;

pub use derivatives::{CoordDerivs, Workspace};
pub use problem::CoxProblem;
pub use state::CoxState;
