//! The crate-wide typed error: every fallible public path — the `CoxFit`
//! builder, the optimizer layer, the compute engines, persistence — returns
//! [`FastSurvivalError`] instead of panicking, so callers can distinguish
//! bad input data from bad configuration from runtime failures.

use std::fmt;

/// Typed error for every fallible FastSurvival operation.
#[derive(Debug)]
pub enum FastSurvivalError {
    /// Input data failed validation (NaN time, empty dataset, shape
    /// mismatch, all-censored training data, ...).
    InvalidData(String),
    /// A configuration was rejected before fitting (negative penalty,
    /// zero iteration budget, ℓ1 with exact Newton, ...).
    InvalidConfig(String),
    /// A component was requested by a name that is not registered.
    Unknown {
        kind: &'static str,
        name: String,
        expected: &'static str,
    },
    /// The requested combination (optimizer × engine, disabled feature)
    /// is not supported.
    Unsupported(String),
    /// A compute-engine failure: missing artifacts, PJRT compilation or
    /// execution errors.
    Engine(String),
    /// The optimizer's loss blew up to a non-finite value. The classic
    /// cause is a Newton-family method on binarized data under weak
    /// regularization (the paper's Figure-1 phenomenon).
    Diverged { optimizer: String, iterations: usize },
    /// The CI perf gate tripped: a tracked kernel regressed past the
    /// committed baseline's tolerance (see `bench --check`).
    PerfRegression(String),
    /// A filesystem operation failed.
    Io {
        context: String,
        source: std::io::Error,
    },
    /// Model persistence (JSON encode/decode) failed.
    Persist(String),
    /// The on-disk columnar dataset store (`.fsds`) is malformed:
    /// wrong magic/version, corrupt or truncated header, payload size
    /// mismatch, or unsorted times.
    Store(String),
    /// The model-serving subsystem failed: artifact-directory layout
    /// violations, bad `name@version` specs, registry reload problems,
    /// or scoring-request validation.
    Serve(String),
}

impl FastSurvivalError {
    /// Shorthand for an [`FastSurvivalError::Io`] with context.
    pub fn io(context: impl Into<String>, source: std::io::Error) -> Self {
        FastSurvivalError::Io { context: context.into(), source }
    }
}

impl fmt::Display for FastSurvivalError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FastSurvivalError::InvalidData(m) => write!(f, "invalid data: {m}"),
            FastSurvivalError::InvalidConfig(m) => write!(f, "invalid configuration: {m}"),
            FastSurvivalError::Unknown { kind, name, expected } => {
                write!(f, "unknown {kind} {name:?} (expected one of: {expected})")
            }
            FastSurvivalError::Unsupported(m) => write!(f, "unsupported: {m}"),
            FastSurvivalError::Engine(m) => write!(f, "engine error: {m}"),
            FastSurvivalError::Diverged { optimizer, iterations } => write!(
                f,
                "optimizer {optimizer:?} diverged after {iterations} iterations \
                 (consider stronger regularization or a surrogate method)"
            ),
            FastSurvivalError::PerfRegression(m) => write!(f, "performance regression: {m}"),
            FastSurvivalError::Io { context, source } => write!(f, "{context}: {source}"),
            FastSurvivalError::Persist(m) => write!(f, "model persistence error: {m}"),
            FastSurvivalError::Store(m) => write!(f, "dataset store error: {m}"),
            FastSurvivalError::Serve(m) => write!(f, "serving error: {m}"),
        }
    }
}

impl std::error::Error for FastSurvivalError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FastSurvivalError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<std::io::Error> for FastSurvivalError {
    fn from(source: std::io::Error) -> Self {
        FastSurvivalError::Io { context: "io error".into(), source }
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, FastSurvivalError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_are_informative() {
        let e = FastSurvivalError::InvalidData("NaN time at sample 3".into());
        assert!(e.to_string().contains("NaN time at sample 3"));
        let e = FastSurvivalError::Unknown {
            kind: "optimizer",
            name: "sgd".into(),
            expected: "quadratic|cubic",
        };
        let s = e.to_string();
        assert!(s.contains("optimizer") && s.contains("sgd") && s.contains("quadratic"));
        let e = FastSurvivalError::Diverged { optimizer: "exact-newton".into(), iterations: 4 };
        assert!(e.to_string().contains("exact-newton"));
    }

    #[test]
    fn io_errors_carry_source() {
        use std::error::Error;
        let e = FastSurvivalError::io(
            "reading model.json",
            std::io::Error::new(std::io::ErrorKind::NotFound, "nope"),
        );
        assert!(e.source().is_some());
        assert!(e.to_string().starts_with("reading model.json"));
    }
}
