//! Random survival forest \[37\] (the paper's SksurvRSF baseline).
//!
//! Bagged log-rank survival trees with per-split feature subsampling;
//! the ensemble cumulative hazard is the average of the trees' Nelson–
//! Aalen leaf estimates.

use super::tree::{SurvivalTree, TreeConfig};
use super::SurvivalModel;
use crate::data::SurvivalDataset;
use crate::linalg::Matrix;
use crate::util::parallel::par_map_indices;
use crate::util::rng::Rng;

/// RSF configuration (paper grid: depth 2..9 × estimators {10,50,100,...}).
#[derive(Clone, Copy, Debug)]
pub struct ForestConfig {
    pub n_trees: usize,
    pub max_depth: usize,
    pub min_leaf: usize,
    pub seed: u64,
}

impl Default for ForestConfig {
    fn default() -> Self {
        ForestConfig { n_trees: 50, max_depth: 4, min_leaf: 10, seed: 2024 }
    }
}

pub struct RandomSurvivalForest {
    trees: Vec<SurvivalTree>,
    /// Fixed horizon grid for the ensemble risk score (sum of cumhaz).
    risk_grid: Vec<f64>,
}

impl RandomSurvivalForest {
    pub fn fit(ds: &SurvivalDataset, cfg: &ForestConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let mtry = ((ds.p() as f64).sqrt().ceil() as usize).max(1);
        // Pre-draw bootstrap seeds so tree fits can run in parallel.
        let seeds: Vec<u64> = (0..cfg.n_trees).map(|_| rng.next_u64()).collect();
        let trees = par_map_indices(cfg.n_trees, |t| {
            let mut trng = Rng::new(seeds[t]);
            let rows = trng.sample_with_replacement(ds.n(), ds.n());
            let boot = ds.subset(&rows);
            SurvivalTree::fit(
                &boot,
                &TreeConfig {
                    max_depth: cfg.max_depth,
                    min_leaf: cfg.min_leaf,
                    mtry,
                    seed: seeds[t] ^ 0xF0F0,
                },
            )
        });
        // Risk grid: deciles of observed event times.
        let mut ev: Vec<f64> = ds
            .time
            .iter()
            .zip(&ds.event)
            .filter(|(_, &e)| e)
            .map(|(&t, _)| t)
            .collect();
        if ev.is_empty() {
            ev = ds.time.clone();
        }
        ev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let risk_grid: Vec<f64> =
            (1..10).map(|d| ev[(d * (ev.len() - 1)) / 10]).collect();
        RandomSurvivalForest { trees, risk_grid }
    }

    /// Ensemble cumulative hazard at (row, t).
    pub fn cumhaz(&self, x: &Matrix, row: usize, t: f64) -> f64 {
        self.trees.iter().map(|tr| tr.cumhaz(x, row, t)).sum::<f64>() / self.trees.len() as f64
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

impl SurvivalModel for RandomSurvivalForest {
    fn name(&self) -> &'static str {
        "random-survival-forest"
    }

    fn predict_risk(&self, x: &Matrix) -> Vec<f64> {
        // Ishwaran's ensemble mortality: sum of CHF over the time grid.
        (0..x.rows)
            .map(|r| self.risk_grid.iter().map(|&t| self.cumhaz(x, r, t)).sum())
            .collect()
    }

    fn predict_survival(&self, x: &Matrix, row: usize, t: f64) -> f64 {
        (-self.cumhaz(x, row, t)).exp()
    }

    fn complexity(&self) -> usize {
        self.trees.iter().map(|t| t.node_count()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::concordance_index;

    fn signal_ds(n: usize, seed: u64) -> SurvivalDataset {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f64>> = (0..5).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let time: Vec<f64> = (0..n)
            .map(|i| rng.exponential() / (1.5 * cols[0][i]).exp())
            .collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.8)).collect();
        SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "sig")
    }

    #[test]
    fn forest_beats_chance() {
        let ds = signal_ds(300, 1);
        let rf = RandomSurvivalForest::fit(&ds, &ForestConfig { n_trees: 20, ..Default::default() });
        let risk = rf.predict_risk(&ds.x);
        let c = concordance_index(&ds.time, &ds.event, &risk);
        assert!(c > 0.65, "c={c}");
    }

    #[test]
    fn survival_in_unit_interval_and_monotone() {
        let ds = signal_ds(150, 2);
        let rf = RandomSurvivalForest::fit(&ds, &ForestConfig { n_trees: 10, ..Default::default() });
        let mut prev = 1.0;
        for t in [0.0, 0.1, 0.5, 1.0, 2.0] {
            let s = rf.predict_survival(&ds.x, 0, t);
            assert!((0.0..=1.0).contains(&s));
            assert!(s <= prev + 1e-12);
            prev = s;
        }
    }

    #[test]
    fn complexity_scales_with_trees() {
        let ds = signal_ds(120, 3);
        let small = RandomSurvivalForest::fit(&ds, &ForestConfig { n_trees: 5, ..Default::default() });
        let big = RandomSurvivalForest::fit(&ds, &ForestConfig { n_trees: 20, ..Default::default() });
        assert!(big.complexity() > small.complexity());
        assert_eq!(small.n_trees(), 5);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = signal_ds(100, 4);
        let a = RandomSurvivalForest::fit(&ds, &ForestConfig { n_trees: 8, seed: 7, ..Default::default() });
        let b = RandomSurvivalForest::fit(&ds, &ForestConfig { n_trees: 8, seed: 7, ..Default::default() });
        assert_eq!(a.predict_risk(&ds.x), b.predict_risk(&ds.x));
    }
}
