//! Non-Cox model classes for the Figure-4 comparison: survival trees
//! (log-rank splitting \[43\]), random survival forests \[37\], gradient-
//! boosted Cox trees, and linear survival SVMs [65, 57].

pub mod forest;
pub mod gbst;
pub mod svm;
pub mod tree;

use crate::data::SurvivalDataset;
use crate::linalg::Matrix;

/// Interface shared by every model class in the Figure-4 experiments.
pub trait SurvivalModel {
    fn name(&self) -> &'static str;

    /// Risk score per row of `x` (higher = expected to fail earlier).
    fn predict_risk(&self, x: &Matrix) -> Vec<f64>;

    /// Predicted survival probability S(t | x_row).
    fn predict_survival(&self, x: &Matrix, row: usize, t: f64) -> f64;

    /// "Support size" proxy as recorded in Appendix C.3: number of tree
    /// nodes for tree-based models, nonzero coefficients for linear ones.
    fn complexity(&self) -> usize;
}

/// Train/test container for model-class experiments.
pub struct ModelEval {
    pub name: String,
    pub complexity: usize,
    pub train_cindex: f64,
    pub test_cindex: f64,
    pub train_ibs: f64,
    pub test_ibs: f64,
}

/// Evaluate a fitted model on train/test splits (CIndex + IBS).
pub fn evaluate_model(
    model: &dyn SurvivalModel,
    train: &SurvivalDataset,
    test: &SurvivalDataset,
) -> ModelEval {
    use crate::metrics::brier::{default_grid, integrated_brier_score};
    use crate::metrics::{concordance_index, KaplanMeier};

    let censor_km = KaplanMeier::fit_censoring(&train.time, &train.event);
    let grid = default_grid(&train.time, &train.event, 30);

    let eval_split = |ds: &SurvivalDataset| -> (f64, f64) {
        let risk = model.predict_risk(&ds.x);
        let ci = concordance_index(&ds.time, &ds.event, &risk);
        let surv = |i: usize, t: f64| model.predict_survival(&ds.x, i, t);
        let ibs = integrated_brier_score(&ds.time, &ds.event, &surv, &censor_km, &grid);
        (ci, ibs)
    };
    let (train_cindex, train_ibs) = eval_split(train);
    let (test_cindex, test_ibs) = eval_split(test);
    ModelEval {
        name: model.name().to_string(),
        complexity: model.complexity(),
        train_cindex,
        test_cindex,
        train_ibs,
        test_ibs,
    }
}
