//! Survival trees.
//!
//! [`SurvivalTree`]: recursive partitioning with the log-rank splitting
//! rule \[43\] and Nelson–Aalen leaf estimates (the sksurv `SurvivalTree`
//! analogue). [`RegressionTree`]: a variance-reduction CART used as the
//! base learner for gradient boosting.

use super::SurvivalModel;
use crate::data::SurvivalDataset;
use crate::linalg::Matrix;
use crate::metrics::km::NelsonAalen;
use crate::util::rng::Rng;

/// Split candidates per feature (quantile-limited for speed).
const MAX_SPLIT_CANDIDATES: usize = 16;

/// Two-sample log-rank statistic (squared, i.e. the chi-square form).
/// Larger = better separation of the two survival curves.
pub fn log_rank_statistic(
    time: &[f64],
    event: &[bool],
    in_left: &[bool],
) -> f64 {
    // Sort event times ascending; walk risk sets for both groups.
    let n = time.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| time[a].partial_cmp(&time[b]).unwrap());

    let mut n_left = in_left.iter().filter(|&&l| l).count() as f64;
    let mut n_total = n as f64;
    let (mut o_minus_e, mut var) = (0.0_f64, 0.0_f64);

    let mut i = 0;
    while i < n {
        let t = time[idx[i]];
        let (mut d_total, mut d_left, mut leave_left, mut leave_total) = (0.0, 0.0, 0.0, 0.0);
        while i < n && time[idx[i]] == t {
            let k = idx[i];
            if event[k] {
                d_total += 1.0;
                if in_left[k] {
                    d_left += 1.0;
                }
            }
            leave_total += 1.0;
            if in_left[k] {
                leave_left += 1.0;
            }
            i += 1;
        }
        if d_total > 0.0 && n_total > 1.0 {
            let e_left = d_total * n_left / n_total;
            o_minus_e += d_left - e_left;
            var += d_total * (n_left / n_total) * (1.0 - n_left / n_total)
                * (n_total - d_total)
                / (n_total - 1.0);
        }
        n_left -= leave_left;
        n_total -= leave_total;
    }
    if var <= 0.0 {
        0.0
    } else {
        o_minus_e * o_minus_e / var
    }
}

#[derive(Clone, Debug)]
enum Node {
    Leaf {
        /// Nelson–Aalen cumulative hazard of the leaf's samples.
        na: NelsonAalen,
        /// Risk score: total cumulative hazard (ranks leaves).
        risk: f64,
    },
    Split {
        feature: usize,
        threshold: f64,
        left: Box<Node>,
        right: Box<Node>,
    },
}

impl Node {
    fn count(&self) -> usize {
        match self {
            Node::Leaf { .. } => 1,
            Node::Split { left, right, .. } => 1 + left.count() + right.count(),
        }
    }

    fn leaf_for<'a>(&'a self, x: &Matrix, row: usize) -> (&'a NelsonAalen, f64) {
        match self {
            Node::Leaf { na, risk } => (na, *risk),
            Node::Split { feature, threshold, left, right } => {
                if x.get(row, *feature) <= *threshold {
                    left.leaf_for(x, row)
                } else {
                    right.leaf_for(x, row)
                }
            }
        }
    }
}

/// Log-rank survival tree.
#[derive(Clone, Debug)]
pub struct SurvivalTree {
    root: Node,
    pub max_depth: usize,
    pub min_leaf: usize,
}

/// Tree-growing options (shared with the forest).
#[derive(Clone, Copy, Debug)]
pub struct TreeConfig {
    pub max_depth: usize,
    pub min_leaf: usize,
    /// Features tried per split (0 = all; forests pass √p).
    pub mtry: usize,
    /// Seed for feature subsampling.
    pub seed: u64,
}

impl Default for TreeConfig {
    fn default() -> Self {
        TreeConfig { max_depth: 4, min_leaf: 10, mtry: 0, seed: 0 }
    }
}

fn grow(
    ds: &SurvivalDataset,
    rows: &[usize],
    depth: usize,
    cfg: &TreeConfig,
    rng: &mut Rng,
) -> Node {
    let make_leaf = |rows: &[usize]| -> Node {
        let time: Vec<f64> = rows.iter().map(|&r| ds.time[r]).collect();
        let event: Vec<bool> = rows.iter().map(|&r| ds.event[r]).collect();
        let na = NelsonAalen::fit(&time, &event);
        let risk = na.cumhaz.last().copied().unwrap_or(0.0);
        Node::Leaf { na, risk }
    };

    if depth >= cfg.max_depth || rows.len() < 2 * cfg.min_leaf {
        return make_leaf(rows);
    }

    // Candidate features.
    let p = ds.p();
    let feats: Vec<usize> = if cfg.mtry == 0 || cfg.mtry >= p {
        (0..p).collect()
    } else {
        rng.sample_indices(p, cfg.mtry)
    };

    let time: Vec<f64> = rows.iter().map(|&r| ds.time[r]).collect();
    let event: Vec<bool> = rows.iter().map(|&r| ds.event[r]).collect();

    let mut best: Option<(f64, usize, f64)> = None; // (stat, feature, threshold)
    for &f in &feats {
        let mut vals: Vec<f64> = rows.iter().map(|&r| ds.x.get(r, f)).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        if vals.len() < 2 {
            continue;
        }
        let step = (vals.len() - 1).div_ceil(MAX_SPLIT_CANDIDATES).max(1);
        for w in (0..vals.len() - 1).step_by(step) {
            let thr = 0.5 * (vals[w] + vals[w + 1]);
            let in_left: Vec<bool> = rows.iter().map(|&r| ds.x.get(r, f) <= thr).collect();
            let n_left = in_left.iter().filter(|&&l| l).count();
            if n_left < cfg.min_leaf || rows.len() - n_left < cfg.min_leaf {
                continue;
            }
            let stat = log_rank_statistic(&time, &event, &in_left);
            if best.map(|(s, _, _)| stat > s).unwrap_or(stat > 0.0) {
                best = Some((stat, f, thr));
            }
        }
    }

    match best {
        None => make_leaf(rows),
        Some((_, f, thr)) => {
            let (lrows, rrows): (Vec<usize>, Vec<usize>) =
                rows.iter().partition(|&&r| ds.x.get(r, f) <= thr);
            Node::Split {
                feature: f,
                threshold: thr,
                left: Box::new(grow(ds, &lrows, depth + 1, cfg, rng)),
                right: Box::new(grow(ds, &rrows, depth + 1, cfg, rng)),
            }
        }
    }
}

impl SurvivalTree {
    pub fn fit(ds: &SurvivalDataset, cfg: &TreeConfig) -> Self {
        let rows: Vec<usize> = (0..ds.n()).collect();
        let mut rng = Rng::new(cfg.seed);
        SurvivalTree {
            root: grow(ds, &rows, 0, cfg, &mut rng),
            max_depth: cfg.max_depth,
            min_leaf: cfg.min_leaf,
        }
    }

    /// Cumulative hazard for a row of x at time t (used by forests).
    pub fn cumhaz(&self, x: &Matrix, row: usize, t: f64) -> f64 {
        let (na, _) = self.root.leaf_for(x, row);
        na.at(t)
    }

    pub fn node_count(&self) -> usize {
        self.root.count()
    }
}

impl SurvivalModel for SurvivalTree {
    fn name(&self) -> &'static str {
        "survival-tree"
    }

    fn predict_risk(&self, x: &Matrix) -> Vec<f64> {
        (0..x.rows).map(|r| self.root.leaf_for(x, r).1).collect()
    }

    fn predict_survival(&self, x: &Matrix, row: usize, t: f64) -> f64 {
        (-self.cumhaz(x, row, t)).exp()
    }

    fn complexity(&self) -> usize {
        self.node_count()
    }
}

/// CART regression tree (variance reduction), base learner for boosting.
#[derive(Clone, Debug)]
pub struct RegressionTree {
    root: RegNode,
}

#[derive(Clone, Debug)]
enum RegNode {
    Leaf(f64),
    Split { feature: usize, threshold: f64, left: Box<RegNode>, right: Box<RegNode> },
}

impl RegNode {
    fn count(&self) -> usize {
        match self {
            RegNode::Leaf(_) => 1,
            RegNode::Split { left, right, .. } => 1 + left.count() + right.count(),
        }
    }
}

fn grow_reg(
    x: &Matrix,
    y: &[f64],
    rows: &[usize],
    depth: usize,
    cfg: &TreeConfig,
    rng: &mut Rng,
) -> RegNode {
    let mean =
        rows.iter().map(|&r| y[r]).sum::<f64>() / rows.len().max(1) as f64;
    if depth >= cfg.max_depth || rows.len() < 2 * cfg.min_leaf {
        return RegNode::Leaf(mean);
    }
    let p = x.cols;
    let feats: Vec<usize> = if cfg.mtry == 0 || cfg.mtry >= p {
        (0..p).collect()
    } else {
        rng.sample_indices(p, cfg.mtry)
    };

    let total_sum: f64 = rows.iter().map(|&r| y[r]).sum();
    let mut best: Option<(f64, usize, f64)> = None;
    for &f in &feats {
        // Sort rows by feature value; scan prefix sums for best SSE split.
        let mut order: Vec<usize> = rows.to_vec();
        order.sort_by(|&a, &b| x.get(a, f).partial_cmp(&x.get(b, f)).unwrap());
        let mut left_sum = 0.0;
        for (i, &r) in order.iter().enumerate() {
            left_sum += y[r];
            if i + 1 < cfg.min_leaf || order.len() - (i + 1) < cfg.min_leaf {
                continue;
            }
            let xv = x.get(r, f);
            let xnext = x.get(order[i + 1], f);
            if xv == xnext {
                continue;
            }
            let nl = (i + 1) as f64;
            let nr = (order.len() - i - 1) as f64;
            let right_sum = total_sum - left_sum;
            // Variance reduction ∝ nl·nr·(mean_l − mean_r)² / (nl+nr).
            let diff = left_sum / nl - right_sum / nr;
            let gain = nl * nr / (nl + nr) * diff * diff;
            if best.map(|(g, _, _)| gain > g).unwrap_or(gain > 1e-12) {
                best = Some((gain, f, 0.5 * (xv + xnext)));
            }
        }
    }
    match best {
        None => RegNode::Leaf(mean),
        Some((_, f, thr)) => {
            let (l, r): (Vec<usize>, Vec<usize>) =
                rows.iter().partition(|&&row| x.get(row, f) <= thr);
            RegNode::Split {
                feature: f,
                threshold: thr,
                left: Box::new(grow_reg(x, y, &l, depth + 1, cfg, rng)),
                right: Box::new(grow_reg(x, y, &r, depth + 1, cfg, rng)),
            }
        }
    }
}

impl RegressionTree {
    pub fn fit(x: &Matrix, y: &[f64], cfg: &TreeConfig) -> Self {
        let rows: Vec<usize> = (0..x.rows).collect();
        let mut rng = Rng::new(cfg.seed);
        RegressionTree { root: grow_reg(x, y, &rows, 0, cfg, &mut rng) }
    }

    pub fn predict_row(&self, x: &Matrix, row: usize) -> f64 {
        let mut node = &self.root;
        loop {
            match node {
                RegNode::Leaf(v) => return *v,
                RegNode::Split { feature, threshold, left, right } => {
                    node = if x.get(row, *feature) <= *threshold { left } else { right };
                }
            }
        }
    }

    pub fn node_count(&self) -> usize {
        self.root.count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_group_ds(n: usize, seed: u64) -> SurvivalDataset {
        // Feature 0 separates fast vs slow failures; feature 1 is noise.
        let mut rng = Rng::new(seed);
        let mut cols = vec![Vec::new(), Vec::new()];
        let mut time = Vec::new();
        let mut event = Vec::new();
        for i in 0..n {
            let fast = i % 2 == 0;
            cols[0].push(if fast { 1.0 } else { 0.0 });
            cols[1].push(rng.normal());
            let base = if fast { 0.5 } else { 3.0 };
            time.push(base + 0.2 * rng.uniform());
            event.push(rng.bernoulli(0.9));
        }
        SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "two")
    }

    #[test]
    fn log_rank_detects_separation() {
        let ds = two_group_ds(80, 1);
        let in_left: Vec<bool> = (0..80).map(|i| ds.x.get(i, 0) > 0.5).collect();
        let strong = log_rank_statistic(&ds.time, &ds.event, &in_left);
        let random: Vec<bool> = (0..80).map(|i| i % 3 == 0).collect();
        let weak = log_rank_statistic(&ds.time, &ds.event, &random);
        assert!(strong > 10.0 * weak.max(1e-9), "strong={strong} weak={weak}");
    }

    #[test]
    fn tree_splits_on_signal_feature() {
        let ds = two_group_ds(100, 2);
        let tree = SurvivalTree::fit(&ds, &TreeConfig { max_depth: 1, ..Default::default() });
        match &tree.root {
            Node::Split { feature, .. } => assert_eq!(*feature, 0),
            Node::Leaf { .. } => panic!("expected a split"),
        }
        // Fast group gets the higher risk.
        let risk = tree.predict_risk(&ds.x);
        let fast_risk = risk[0];
        let slow_risk = risk[1];
        assert!(fast_risk > slow_risk, "{fast_risk} vs {slow_risk}");
    }

    #[test]
    fn survival_monotone_in_time() {
        let ds = two_group_ds(100, 3);
        let tree = SurvivalTree::fit(&ds, &TreeConfig::default());
        let mut prev = 1.0;
        for t in [0.0, 0.5, 1.0, 2.0, 3.0, 4.0] {
            let s = tree.predict_survival(&ds.x, 0, t);
            assert!(s <= prev + 1e-12 && (0.0..=1.0).contains(&s));
            prev = s;
        }
    }

    #[test]
    fn min_leaf_respected() {
        let ds = two_group_ds(30, 4);
        let tree =
            SurvivalTree::fit(&ds, &TreeConfig { max_depth: 10, min_leaf: 20, ..Default::default() });
        assert_eq!(tree.node_count(), 1, "cannot split 30 rows with min_leaf 20");
    }

    #[test]
    fn regression_tree_fits_step_function() {
        let x = Matrix::from_columns(&[(0..50).map(|i| i as f64).collect()]);
        let y: Vec<f64> = (0..50).map(|i| if i < 25 { 1.0 } else { 5.0 }).collect();
        let t = RegressionTree::fit(&x, &y, &TreeConfig { max_depth: 2, min_leaf: 5, ..Default::default() });
        assert!((t.predict_row(&x, 3) - 1.0).abs() < 0.2);
        assert!((t.predict_row(&x, 45) - 5.0).abs() < 0.2);
    }
}
