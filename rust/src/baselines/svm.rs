//! Linear survival SVMs.
//!
//! [`NaiveSurvivalSvm`] \[65\]: ranking formulation over all comparable
//! pairs with squared hinge loss, optimized by full-gradient descent —
//! O(n²) per iteration (the paper notes this baseline frequently timed
//! out). [`FastSurvivalSvm`] \[57\]: the same objective restricted to
//! adjacent comparable pairs in time order, O(n log n) per iteration —
//! the order-statistic speedup idea of Pölsterl et al.

use super::SurvivalModel;
use crate::data::SurvivalDataset;
use crate::linalg::Matrix;
use crate::metrics::BreslowBaseline;

/// Shared hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct SvmConfig {
    /// ℓ2 regularization weight α (paper grid: 0.01 … 100).
    pub alpha: f64,
    pub max_iters: usize,
    pub lr: f64,
}

impl Default for SvmConfig {
    fn default() -> Self {
        SvmConfig { alpha: 1.0, max_iters: 200, lr: 0.05 }
    }
}

/// Comparable pairs (i, j): t_i < t_j and δ_i = 1. The model wants
/// w·x_i − w·x_j ≥ 1 (earlier failure = higher score).
fn comparable_pairs(time: &[f64], event: &[bool], adjacent_only: bool) -> Vec<(usize, usize)> {
    let n = time.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| time[a].partial_cmp(&time[b]).unwrap());
    let mut pairs = Vec::new();
    for (a, &i) in idx.iter().enumerate() {
        if !event[i] {
            continue;
        }
        for &j in &idx[a + 1..] {
            if time[j] <= time[i] {
                continue;
            }
            pairs.push((i, j));
            if adjacent_only {
                break; // only the nearest later neighbor
            }
        }
    }
    pairs
}

fn fit_ranking_svm(ds: &SurvivalDataset, cfg: &SvmConfig, adjacent_only: bool) -> Vec<f64> {
    let p = ds.p();
    let pairs = comparable_pairs(&ds.time, &ds.event, adjacent_only);
    let mut w = vec![0.0_f64; p];
    if pairs.is_empty() {
        return w;
    }
    let scale = 1.0 / pairs.len() as f64;
    // Keep the ridge-part contraction stable: lr·α must stay below 1.
    let lr = cfg.lr.min(0.5 / cfg.alpha.max(1e-9));
    for _ in 0..cfg.max_iters {
        // Gradient of α‖w‖²/2 + mean squared hinge.
        let mut grad: Vec<f64> = w.iter().map(|&v| cfg.alpha * v).collect();
        let scores: Vec<f64> = ds.x.matvec(&w);
        for &(i, j) in &pairs {
            let margin = 1.0 - (scores[i] - scores[j]);
            if margin > 0.0 {
                // d/dw [margin²] = −2·margin·(x_i − x_j)
                for l in 0..p {
                    grad[l] -= 2.0 * margin * (ds.x.get(i, l) - ds.x.get(j, l)) * scale;
                }
            }
        }
        for l in 0..p {
            w[l] -= lr * grad[l];
        }
    }
    w
}

/// Common SVM wrapper (risk = w·x; survival via Breslow on train scores).
pub struct LinearSurvivalSvm {
    pub w: Vec<f64>,
    baseline: BreslowBaseline,
    name: &'static str,
}

impl LinearSurvivalSvm {
    fn finish(ds: &SurvivalDataset, w: Vec<f64>, name: &'static str) -> Self {
        let eta = ds.x.matvec(&w);
        let baseline = BreslowBaseline::fit(&ds.time, &ds.event, &eta);
        LinearSurvivalSvm { w, baseline, name }
    }
}

/// Naive all-pairs ranking SVM \[65\].
pub struct NaiveSurvivalSvm;
impl NaiveSurvivalSvm {
    pub fn fit(ds: &SurvivalDataset, cfg: &SvmConfig) -> LinearSurvivalSvm {
        LinearSurvivalSvm::finish(ds, fit_ranking_svm(ds, cfg, false), "naive-survival-svm")
    }
}

/// Fast adjacent-pairs ranking SVM \[57\].
pub struct FastSurvivalSvm;
impl FastSurvivalSvm {
    pub fn fit(ds: &SurvivalDataset, cfg: &SvmConfig) -> LinearSurvivalSvm {
        LinearSurvivalSvm::finish(ds, fit_ranking_svm(ds, cfg, true), "fast-survival-svm")
    }
}

impl SurvivalModel for LinearSurvivalSvm {
    fn name(&self) -> &'static str {
        self.name
    }

    fn predict_risk(&self, x: &Matrix) -> Vec<f64> {
        x.matvec(&self.w)
    }

    fn predict_survival(&self, x: &Matrix, row: usize, t: f64) -> f64 {
        let score: f64 = (0..x.cols).map(|l| x.get(row, l) * self.w[l]).sum();
        self.baseline.survival(t, score)
    }

    fn complexity(&self) -> usize {
        self.w.iter().filter(|v| v.abs() > 1e-10).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::concordance_index;
    use crate::util::rng::Rng;

    fn signal_ds(n: usize, seed: u64) -> SurvivalDataset {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f64>> = (0..3).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let time: Vec<f64> = (0..n).map(|i| rng.exponential() / (1.5 * cols[0][i]).exp()).collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.8)).collect();
        SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "sig")
    }

    #[test]
    fn comparable_pairs_structure() {
        let time = vec![1.0, 2.0, 3.0];
        let event = vec![true, false, true];
        let all = comparable_pairs(&time, &event, false);
        // i=0 pairs with 1 and 2; i=1 censored; i=2 has nothing later.
        assert_eq!(all, vec![(0, 1), (0, 2)]);
        let adj = comparable_pairs(&time, &event, true);
        assert_eq!(adj, vec![(0, 1)]);
    }

    #[test]
    fn naive_svm_learns_ranking() {
        let ds = signal_ds(120, 1);
        let m = NaiveSurvivalSvm::fit(&ds, &SvmConfig::default());
        let c = concordance_index(&ds.time, &ds.event, &m.predict_risk(&ds.x));
        assert!(c > 0.7, "c={c}");
        // Signal feature must dominate the weight vector.
        assert!(m.w[0] > m.w[1].abs().max(m.w[2].abs()));
    }

    #[test]
    fn fast_svm_close_to_naive() {
        let ds = signal_ds(150, 2);
        let naive = NaiveSurvivalSvm::fit(&ds, &SvmConfig::default());
        let fast = FastSurvivalSvm::fit(&ds, &SvmConfig::default());
        let cn = concordance_index(&ds.time, &ds.event, &naive.predict_risk(&ds.x));
        let cf = concordance_index(&ds.time, &ds.event, &fast.predict_risk(&ds.x));
        assert!(cf > 0.6, "fast SVM must still rank well: {cf}");
        assert!((cn - cf).abs() < 0.25, "naive {cn} vs fast {cf}");
    }

    #[test]
    fn stronger_alpha_shrinks_weights() {
        let ds = signal_ds(100, 3);
        let weak = NaiveSurvivalSvm::fit(&ds, &SvmConfig { alpha: 0.01, ..Default::default() });
        let strong = NaiveSurvivalSvm::fit(&ds, &SvmConfig { alpha: 50.0, ..Default::default() });
        let nw: f64 = weak.w.iter().map(|v| v * v).sum();
        let ns: f64 = strong.w.iter().map(|v| v * v).sum();
        assert!(ns < nw, "{ns} vs {nw}");
    }
}
