//! Gradient-boosted Cox model (the paper's SksurvGBST baseline).
//!
//! Stagewise additive score F(x): each stage fits a regression tree to
//! the negative gradient of the Cox partial likelihood w.r.t. η = F(x)
//! and adds it with a learning rate. Survival curves come from the
//! Breslow baseline on the final training scores.

use super::tree::{RegressionTree, TreeConfig};
use super::SurvivalModel;
use crate::cox::derivatives::eta_gradient;
use crate::cox::{CoxProblem, CoxState};
use crate::data::SurvivalDataset;
use crate::linalg::Matrix;
use crate::metrics::BreslowBaseline;

#[derive(Clone, Copy, Debug)]
pub struct GbstConfig {
    pub n_stages: usize,
    pub learning_rate: f64,
    pub max_depth: usize,
    pub min_leaf: usize,
    pub seed: u64,
}

impl Default for GbstConfig {
    fn default() -> Self {
        GbstConfig { n_stages: 100, learning_rate: 0.1, max_depth: 3, min_leaf: 10, seed: 2024 }
    }
}

pub struct GradientBoostedCox {
    stages: Vec<RegressionTree>,
    learning_rate: f64,
    baseline: BreslowBaseline,
}

impl GradientBoostedCox {
    pub fn fit(ds: &SurvivalDataset, cfg: &GbstConfig) -> Self {
        let problem = CoxProblem::new(ds);
        let n = ds.n();
        let mut stages = Vec::with_capacity(cfg.n_stages);
        // Score in *sorted* order (problem space) for gradient computation,
        // and in original order for tree fitting.
        let mut f_orig = vec![0.0_f64; n];
        for stage in 0..cfg.n_stages {
            // η in sorted order.
            let eta_sorted: Vec<f64> =
                problem.order.iter().map(|&orig| f_orig[orig]).collect();
            let mut state = CoxState::zeros(&problem);
            state.eta = eta_sorted;
            state.refresh_w();
            let u_sorted = eta_gradient(&problem, &state);
            // Negative gradient back in original order.
            let mut target = vec![0.0_f64; n];
            for (pos, &orig) in problem.order.iter().enumerate() {
                target[orig] = -u_sorted[pos];
            }
            let tree = RegressionTree::fit(
                &ds.x,
                &target,
                &TreeConfig {
                    max_depth: cfg.max_depth,
                    min_leaf: cfg.min_leaf,
                    mtry: 0,
                    seed: cfg.seed ^ (stage as u64),
                },
            );
            for i in 0..n {
                f_orig[i] += cfg.learning_rate * tree.predict_row(&ds.x, i);
            }
            stages.push(tree);
        }
        let baseline = BreslowBaseline::fit(&ds.time, &ds.event, &f_orig);
        GradientBoostedCox { stages, learning_rate: cfg.learning_rate, baseline }
    }

    /// Additive score F(x_row).
    pub fn score(&self, x: &Matrix, row: usize) -> f64 {
        self.stages
            .iter()
            .map(|t| self.learning_rate * t.predict_row(x, row))
            .sum()
    }
}

impl SurvivalModel for GradientBoostedCox {
    fn name(&self) -> &'static str {
        "gradient-boosted-cox"
    }

    fn predict_risk(&self, x: &Matrix) -> Vec<f64> {
        (0..x.rows).map(|r| self.score(x, r)).collect()
    }

    fn predict_survival(&self, x: &Matrix, row: usize, t: f64) -> f64 {
        self.baseline.survival(t, self.score(x, row))
    }

    fn complexity(&self) -> usize {
        self.stages.iter().map(|t| t.node_count()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::concordance_index;
    use crate::util::rng::Rng;

    fn signal_ds(n: usize, seed: u64) -> SurvivalDataset {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f64>> = (0..4).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let time: Vec<f64> = (0..n)
            .map(|i| rng.exponential() / (1.2 * cols[0][i] - 0.8 * cols[1][i]).exp())
            .collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.8)).collect();
        SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "sig")
    }

    #[test]
    fn boosting_learns_signal() {
        let ds = signal_ds(250, 1);
        let gb = GradientBoostedCox::fit(&ds, &GbstConfig { n_stages: 40, ..Default::default() });
        let c = concordance_index(&ds.time, &ds.event, &gb.predict_risk(&ds.x));
        assert!(c > 0.7, "c={c}");
    }

    #[test]
    fn more_stages_fit_train_better() {
        let ds = signal_ds(200, 2);
        let few = GradientBoostedCox::fit(&ds, &GbstConfig { n_stages: 5, ..Default::default() });
        let many = GradientBoostedCox::fit(&ds, &GbstConfig { n_stages: 80, ..Default::default() });
        let c_few = concordance_index(&ds.time, &ds.event, &few.predict_risk(&ds.x));
        let c_many = concordance_index(&ds.time, &ds.event, &many.predict_risk(&ds.x));
        assert!(c_many >= c_few - 1e-9, "{c_many} vs {c_few}");
    }

    #[test]
    fn survival_valid_probabilities() {
        let ds = signal_ds(150, 3);
        let gb = GradientBoostedCox::fit(&ds, &GbstConfig { n_stages: 20, ..Default::default() });
        for t in [0.1, 0.5, 1.0, 3.0] {
            let s = gb.predict_survival(&ds.x, 0, t);
            assert!((0.0..=1.0).contains(&s), "s={s}");
        }
    }
}
