//! `CoxPath`: the fitted whole-family estimator returned by
//! [`crate::api::CoxFit::l1_path`] and
//! [`crate::api::CoxFit::cardinality_path`].
//!
//! A path holds one entry per grid point — λ for regularization paths,
//! support size k for cardinality paths — each with its coefficient
//! vector, training loss, and a fitted Breslow baseline, so any point
//! can be materialized as a full [`CoxModel`] (prediction, evaluation,
//! JSON persistence) without refitting. The path itself round-trips
//! through the same in-repo JSON layer as single models.

use super::json;
use super::model::{report_from_json, write_report_field, CoxModel, FitDiagnostics};
use crate::error::{FastSurvivalError, Result};
use crate::metrics::BreslowBaseline;
use crate::obs::FitReport;
use crate::optim::Trace;
use std::path::Path;

/// Version tag written into saved path files.
const PATH_FORMAT_VERSION: usize = 1;

/// What family a [`CoxPath`] holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PathKind {
    /// λ-path: ℓ1(+ℓ2) penalized solutions on a descending λ grid.
    L1,
    /// k-path: cardinality-constrained solutions for k = 1..K.
    Cardinality,
}

impl PathKind {
    pub fn name(self) -> &'static str {
        match self {
            PathKind::L1 => "l1",
            PathKind::Cardinality => "cardinality",
        }
    }

    fn from_name(name: &str) -> Result<Self> {
        match name {
            "l1" => Ok(PathKind::L1),
            "cardinality" => Ok(PathKind::Cardinality),
            other => Err(FastSurvivalError::Persist(format!(
                "unknown path kind {other:?} (expected l1|cardinality)"
            ))),
        }
    }
}

/// One fitted point on a path.
#[derive(Clone, Debug)]
pub struct CoxPathPoint {
    /// Grid λ (None on cardinality paths).
    pub lambda: Option<f64>,
    /// Support size (nonzero coefficients).
    pub k: usize,
    /// Effective penalties the point was fitted with (0 on k-paths).
    pub l1: f64,
    pub l2: f64,
    /// Dense coefficient vector.
    pub beta: Vec<f64>,
    /// Unpenalized CPH training loss.
    pub train_loss: f64,
    /// CD sweeps spent on this point (0 where the solver does not track it).
    pub iterations: usize,
    pub(crate) baseline: BreslowBaseline,
}

/// A fitted family of Cox models: per-λ or per-k solutions, each
/// materializable as a [`CoxModel`].
#[derive(Clone, Debug)]
pub struct CoxPath {
    kind: PathKind,
    feature_names: Vec<String>,
    points: Vec<CoxPathPoint>,
    optimizer: String,
    n_train: usize,
    n_events: usize,
    wall_secs: f64,
    /// Observability report for the whole path solve, captured when
    /// tracing was enabled ([`crate::obs::set_enabled`]).
    report: Option<FitReport>,
}

impl CoxPath {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_parts(
        kind: PathKind,
        feature_names: Vec<String>,
        points: Vec<CoxPathPoint>,
        optimizer: String,
        n_train: usize,
        n_events: usize,
        wall_secs: f64,
    ) -> Self {
        CoxPath {
            kind,
            feature_names,
            points,
            optimizer,
            n_train,
            n_events,
            wall_secs,
            report: None,
        }
    }

    /// Per-phase span timings and engine counters for the whole path
    /// solve (None unless tracing was enabled during the fit).
    pub fn report(&self) -> Option<&FitReport> {
        self.report.as_ref()
    }

    pub(crate) fn set_report(&mut self, report: Option<FitReport>) {
        self.report = report;
    }

    pub fn kind(&self) -> PathKind {
        self.kind
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn points(&self) -> &[CoxPathPoint] {
        &self.points
    }

    pub fn feature_names(&self) -> &[String] {
        &self.feature_names
    }

    /// Wall-clock seconds spent fitting the whole path.
    pub fn wall_secs(&self) -> f64 {
        self.wall_secs
    }

    /// The λ grid (empty on cardinality paths).
    pub fn lambdas(&self) -> Vec<f64> {
        self.points.iter().filter_map(|p| p.lambda).collect()
    }

    /// Support size per point, in path order.
    pub fn support_sizes(&self) -> Vec<usize> {
        self.points.iter().map(|p| p.k).collect()
    }

    fn diagnostics_for(&self, pt: &CoxPathPoint) -> FitDiagnostics {
        FitDiagnostics {
            optimizer: self.optimizer.clone(),
            engine: "native".to_string(),
            iterations: pt.iterations,
            converged: true,
            budget_exhausted: false,
            objective_value: pt.train_loss,
            l1: pt.l1,
            l2: pt.l2,
            n_train: self.n_train,
            n_events: self.n_events,
            wall_secs: self.wall_secs,
            trace: Trace::default(),
            report: None,
        }
    }

    /// Materialize the `i`-th point as a full [`CoxModel`].
    pub fn model_at(&self, i: usize) -> Result<CoxModel> {
        let pt = self.points.get(i).ok_or_else(|| {
            FastSurvivalError::InvalidConfig(format!(
                "path index {i} out of range (path has {} points)",
                self.points.len()
            ))
        })?;
        Ok(CoxModel::from_parts(
            self.feature_names.clone(),
            pt.beta.clone(),
            pt.baseline.clone(),
            self.diagnostics_for(pt),
        ))
    }

    /// The model at the grid point whose λ is closest to `lambda`
    /// (λ-paths only).
    pub fn model_for_lambda(&self, lambda: f64) -> Result<CoxModel> {
        if self.kind != PathKind::L1 {
            return Err(FastSurvivalError::InvalidConfig(
                "model_for_lambda on a cardinality path; use model_for_k".into(),
            ));
        }
        let (i, _) = self
            .points
            .iter()
            .enumerate()
            .filter(|(_, p)| p.lambda.is_some())
            .min_by(|a, b| {
                let da = (a.1.lambda.unwrap_or(f64::INFINITY) - lambda).abs();
                let db = (b.1.lambda.unwrap_or(f64::INFINITY) - lambda).abs();
                da.partial_cmp(&db).unwrap_or(std::cmp::Ordering::Equal)
            })
            .ok_or_else(|| FastSurvivalError::InvalidConfig("empty path".into()))?;
        self.model_at(i)
    }

    /// The model with exactly `k` nonzero coefficients; on λ-paths, the
    /// best-loss point among those that hit `k` exactly.
    pub fn model_for_k(&self, k: usize) -> Result<CoxModel> {
        let (i, _) = self
            .points
            .iter()
            .enumerate()
            .filter(|(_, p)| p.k == k)
            .min_by(|a, b| {
                a.1.train_loss
                    .partial_cmp(&b.1.train_loss)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .ok_or_else(|| {
                FastSurvivalError::InvalidConfig(format!(
                    "no path point has support size {k}"
                ))
            })?;
        self.model_at(i)
    }

    // ---------------------------------------------------- persistence

    /// Serialize to the versioned JSON path format.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(8192);
        out.push_str("{\n  \"path_format_version\": ");
        out.push_str(&PATH_FORMAT_VERSION.to_string());
        out.push_str(",\n  \"kind\": ");
        json::write_str(&mut out, self.kind.name());
        out.push_str(",\n  \"optimizer\": ");
        json::write_str(&mut out, &self.optimizer);
        out.push_str(&format!(",\n  \"n_train\": {}", self.n_train));
        out.push_str(&format!(",\n  \"n_events\": {}", self.n_events));
        out.push_str(",\n  \"wall_secs\": ");
        json::write_f64(&mut out, self.wall_secs);
        out.push_str(",\n  \"report\": ");
        write_report_field(&mut out, &self.report);
        out.push_str(",\n  \"feature_names\": ");
        json::write_str_array(&mut out, &self.feature_names);
        out.push_str(",\n  \"points\": [\n");
        for (i, pt) in self.points.iter().enumerate() {
            out.push_str("    {\"lambda\": ");
            match pt.lambda {
                Some(l) => json::write_f64(&mut out, l),
                None => out.push_str("null"),
            }
            out.push_str(&format!(", \"k\": {}", pt.k));
            out.push_str(", \"l1\": ");
            json::write_f64(&mut out, pt.l1);
            out.push_str(", \"l2\": ");
            json::write_f64(&mut out, pt.l2);
            out.push_str(", \"train_loss\": ");
            json::write_f64(&mut out, pt.train_loss);
            out.push_str(&format!(", \"iterations\": {}", pt.iterations));
            out.push_str(", \"beta\": ");
            json::write_f64_array(&mut out, &pt.beta);
            out.push_str(", \"baseline\": {\"times\": ");
            json::write_f64_array(&mut out, &pt.baseline.times);
            out.push_str(", \"cumhaz\": ");
            json::write_f64_array(&mut out, &pt.baseline.cumhaz);
            out.push_str("}}");
            out.push_str(if i + 1 < self.points.len() { ",\n" } else { "\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Rebuild a path from [`CoxPath::to_json`] output.
    pub fn from_json(text: &str) -> Result<Self> {
        let doc = json::parse(text)?;
        let version = doc.require("path_format_version")?.as_usize()?;
        if version != PATH_FORMAT_VERSION {
            return Err(FastSurvivalError::Persist(format!(
                "unsupported path_format_version {version} (this build reads {PATH_FORMAT_VERSION})"
            )));
        }
        let kind = PathKind::from_name(doc.require("kind")?.as_str()?)?;
        let feature_names = doc.require("feature_names")?.as_string_vec()?;
        let optimizer = doc.require("optimizer")?.as_str()?.to_string();
        let n_train = doc.require("n_train")?.as_usize()?;
        let n_events = doc.require("n_events")?.as_usize()?;
        let wall_secs = doc.require("wall_secs")?.as_f64()?;
        let report = report_from_json(&doc)?;
        let mut points = Vec::new();
        for p in doc.require("points")?.as_array()? {
            let lambda = match p.require("lambda")? {
                json::Json::Null => None,
                v => Some(v.as_f64()?),
            };
            let beta = p.require("beta")?.as_f64_vec()?;
            if beta.len() != feature_names.len() {
                return Err(FastSurvivalError::Persist(format!(
                    "corrupt path: {} coefficients vs {} feature names",
                    beta.len(),
                    feature_names.len()
                )));
            }
            if beta.iter().any(|b| !b.is_finite()) {
                return Err(FastSurvivalError::Persist(
                    "corrupt path: non-finite coefficient".into(),
                ));
            }
            let bl = p.require("baseline")?;
            let baseline = BreslowBaseline::from_parts(
                bl.require("times")?.as_f64_vec()?,
                bl.require("cumhaz")?.as_f64_vec()?,
            )?;
            points.push(CoxPathPoint {
                lambda,
                k: p.require("k")?.as_usize()?,
                l1: p.require("l1")?.as_f64()?,
                l2: p.require("l2")?.as_f64()?,
                beta,
                train_loss: p.require("train_loss")?.as_f64()?,
                iterations: p.require("iterations")?.as_usize()?,
                baseline,
            });
        }
        Ok(CoxPath {
            kind,
            feature_names,
            points,
            optimizer,
            n_train,
            n_events,
            wall_secs,
            report,
        })
    }

    /// Save to a JSON file (parent directories are created).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| FastSurvivalError::io(format!("creating {parent:?}"), e))?;
            }
        }
        std::fs::write(path, self.to_json())
            .map_err(|e| FastSurvivalError::io(format!("writing path to {path:?}"), e))
    }

    /// Load a path saved by [`CoxPath::save`].
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| FastSurvivalError::io(format!("reading path from {path:?}"), e))?;
        Self::from_json(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_path() -> CoxPath {
        let baseline = BreslowBaseline::fit(
            &[1.0, 2.0, 3.0, 4.0],
            &[true, true, false, true],
            &[0.2, -0.1, 0.4, 0.0],
        );
        let points = vec![
            CoxPathPoint {
                lambda: Some(1.0),
                k: 0,
                l1: 1.0,
                l2: 0.0,
                beta: vec![0.0, 0.0],
                train_loss: 5.0,
                iterations: 1,
                baseline: baseline.clone(),
            },
            CoxPathPoint {
                lambda: Some(0.1),
                k: 2,
                l1: 0.1,
                l2: 0.0,
                beta: vec![0.75, -0.25],
                train_loss: 3.5,
                iterations: 7,
                baseline,
            },
        ];
        CoxPath::from_parts(
            PathKind::L1,
            vec!["age".into(), "bp".into()],
            points,
            "cubic-surrogate".into(),
            4,
            3,
            0.02,
        )
    }

    #[test]
    fn json_round_trip_is_exact() {
        let p = toy_path();
        let r = CoxPath::from_json(&p.to_json()).unwrap();
        assert_eq!(r.kind(), PathKind::L1);
        assert_eq!(r.len(), 2);
        assert_eq!(r.feature_names(), p.feature_names());
        for (a, b) in p.points().iter().zip(r.points().iter()) {
            assert_eq!(a.lambda, b.lambda);
            assert_eq!(a.k, b.k);
            assert_eq!(a.beta, b.beta);
            assert_eq!(a.train_loss, b.train_loss);
            assert_eq!(a.baseline.times, b.baseline.times);
            assert_eq!(a.baseline.cumhaz, b.baseline.cumhaz);
        }
        assert!(r.report().is_none());
    }

    #[test]
    fn fit_report_round_trips_on_the_path() {
        let mut p = toy_path();
        p.set_report(Some(FitReport {
            phases: vec![crate::obs::report::PhaseReport {
                phase: "path_screen".into(),
                count: 12,
                total_ns: 4000,
                self_ns: 4000,
            }],
            counters: crate::obs::CounterSnapshot {
                screened_skips: 30,
                kkt_repair_rounds: 2,
                ..Default::default()
            },
        }));
        let r = CoxPath::from_json(&p.to_json()).unwrap();
        assert_eq!(r.report(), p.report());
    }

    #[test]
    fn models_materialize_with_point_penalties() {
        let p = toy_path();
        let m = p.model_at(1).unwrap();
        assert_eq!(m.beta(), &[0.75, -0.25]);
        assert_eq!(m.diagnostics().l1, 0.1);
        let closest = p.model_for_lambda(0.12).unwrap();
        assert_eq!(closest.beta(), &[0.75, -0.25]);
        let by_k = p.model_for_k(2).unwrap();
        assert_eq!(by_k.beta(), &[0.75, -0.25]);
        assert!(p.model_at(9).is_err());
        assert!(p.model_for_k(5).is_err());
    }

    #[test]
    fn load_rejects_corrupt_documents() {
        let p = toy_path();
        let good = p.to_json();
        assert!(CoxPath::from_json("{}").is_err());
        assert!(CoxPath::from_json(
            &good.replace("\"path_format_version\": 1", "\"path_format_version\": 9")
        )
        .is_err());
        assert!(CoxPath::from_json(&good.replace("\"kind\": \"l1\"", "\"kind\": \"l7\"")).is_err());
        assert!(CoxPath::from_json(&good[..good.len() / 2]).is_err());
    }
}
