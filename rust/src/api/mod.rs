//! The public estimator API — the stable surface every workload targets.
//!
//! [`CoxFit`] is a lifelines/scikit-survival-style builder: choose
//! penalties, an optimizer ([`OptimizerKind`]), and a compute engine
//! ([`EngineKind`]), call [`CoxFit::fit`] on a
//! [`crate::data::SurvivalDataset`], and get a [`CoxModel`] that owns
//! the coefficients, the fitted Breslow baseline, and fit diagnostics,
//! with `predict_risk` / `predict_survival` / `concordance` and JSON
//! `save` / `load`.
//!
//! Everything underneath — problem preprocessing, engines, optimizers,
//! metrics — stays public for power users, but fallible paths route
//! through [`crate::error::FastSurvivalError`] here rather than
//! panicking.

pub mod builder;
pub mod json;
pub mod model;

pub use builder::{CoxFit, EngineKind, OptimizerKind};
pub use model::{Coefficient, CoxModel, FitDiagnostics};
