//! The public estimator API — the stable surface every workload targets.
//!
//! [`CoxFit`] is a lifelines/scikit-survival-style builder: choose
//! penalties, an optimizer ([`OptimizerKind`]), and a compute engine
//! ([`EngineKind`]), call [`CoxFit::fit`] on a
//! [`crate::data::SurvivalDataset`], and get a [`CoxModel`] that owns
//! the coefficients, the fitted Breslow baseline, and fit diagnostics,
//! with `predict_risk` / `predict_survival` / `concordance` and JSON
//! `save` / `load`. Whole model families come from the same builder:
//! [`CoxFit::l1_path`] (warm-started screened λ-path) and
//! [`CoxFit::cardinality_path`] (k = 1..K) return a [`CoxPath`] whose
//! every point materializes as a `CoxModel`.
//!
//! Everything underneath — problem preprocessing, engines, optimizers,
//! metrics — stays public for power users, but fallible paths route
//! through [`crate::error::FastSurvivalError`] here rather than
//! panicking.

pub mod builder;
pub mod json;
pub mod model;
pub mod path;

pub use builder::{CoxFit, EngineKind, OptimizerKind};
pub use model::{Coefficient, CoxModel, FitDiagnostics};
pub use path::{CoxPath, CoxPathPoint, PathKind};
