//! The `CoxFit` builder: one fluent entry point that assembles the
//! problem, the compute engine, and the optimizer, fits, and returns a
//! [`CoxModel`].
//!
//! ```no_run
//! use fastsurvival::api::{CoxFit, EngineKind, OptimizerKind};
//! # let ds = fastsurvival::data::synthetic::generate(&Default::default());
//! let model = CoxFit::new()
//!     .l1(0.5)
//!     .l2(0.1)
//!     .optimizer(OptimizerKind::Cubic)
//!     .engine(EngineKind::Native)
//!     .max_iters(200)
//!     .fit(&ds)?;
//! let risk = model.predict_risk(&ds.x)?;
//! # Ok::<(), fastsurvival::error::FastSurvivalError>(())
//! ```

use super::model::{CoxModel, FitDiagnostics};
use super::path::{CoxPath, CoxPathPoint, PathKind};
use crate::cox::{CoxProblem, CoxState};
use crate::data::SurvivalDataset;
use crate::error::{FastSurvivalError, Result};
use crate::metrics::BreslowBaseline;
use crate::obs::{obs_snapshot, FitReport, ObsSnapshot};
use crate::optim::{FitConfig, Objective, Optimizer, SurrogateKind};
use crate::path::{CardinalityPath, CardinalitySolver, PathSolver};
use crate::runtime::engine::CoxEngine;
use crate::select::BeamSearch;
use crate::store::{ChunkedDataset, CoxData, StreamingFit};
use crate::util::compute::{Compute, Precision};
use std::borrow::Cow;
use std::path::{Path, PathBuf};
use std::time::Instant;

// The typed registries live with the layers they enumerate; the api
// module re-exports them as part of the stable surface.
pub use crate::optim::OptimizerKind;
pub use crate::runtime::engine::EngineKind;

/// Fluent builder for fitting a Cox proportional hazards model.
///
/// Defaults: cubic surrogate, native engine, no regularization,
/// `max_iters = 200`, `tol = 1e-9`, unlimited wall clock.
#[derive(Clone, Debug)]
pub struct CoxFit {
    l1: f64,
    l2: f64,
    optimizer: OptimizerKind,
    engine: EngineKind,
    artifact_dir: PathBuf,
    max_iters: usize,
    tol: f64,
    stop_kkt: f64,
    budget_secs: f64,
    record_trace: bool,
    compute: Compute,
    // λ-path configuration (CoxFit::l1_path).
    n_lambdas: usize,
    lambda_min_ratio: f64,
    l1_ratio: f64,
}

impl Default for CoxFit {
    fn default() -> Self {
        CoxFit {
            l1: 0.0,
            l2: 0.0,
            optimizer: OptimizerKind::Cubic,
            engine: EngineKind::Native,
            artifact_dir: PathBuf::from("artifacts"),
            max_iters: 200,
            tol: 1e-9,
            stop_kkt: 0.0,
            budget_secs: 0.0,
            record_trace: true,
            compute: Compute::default(),
            n_lambdas: 50,
            lambda_min_ratio: 0.01,
            l1_ratio: 1.0,
        }
    }
}

impl CoxFit {
    pub fn new() -> Self {
        Self::default()
    }

    /// ℓ1 (lasso) penalty weight λ1 ≥ 0.
    pub fn l1(mut self, l1: f64) -> Self {
        self.l1 = l1;
        self
    }

    /// ℓ2 (ridge) penalty weight λ2 ≥ 0.
    pub fn l2(mut self, l2: f64) -> Self {
        self.l2 = l2;
        self
    }

    pub fn optimizer(mut self, kind: OptimizerKind) -> Self {
        self.optimizer = kind;
        self
    }

    pub fn engine(mut self, kind: EngineKind) -> Self {
        self.engine = kind;
        self
    }

    /// Directory holding the AOT artifacts (`manifest.tsv`) for
    /// [`EngineKind::Xla`].
    pub fn artifact_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifact_dir = dir.into();
        self
    }

    /// Maximum outer iterations (CD sweeps / Newton steps).
    pub fn max_iters(mut self, iters: usize) -> Self {
        self.max_iters = iters;
        self
    }

    /// Relative loss-decrease convergence tolerance.
    pub fn tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    /// KKT-residual stopping for [`CoxFit::fit_store`] (0 = off,
    /// the default): stop the exact chunked phase once every
    /// coordinate's pre-step KKT residual is ≤ `eps`. Residual stopping
    /// bounds the distance to the optimum directly, which is what
    /// certifies ≤1e-8 agreement with an independently-run in-memory
    /// fit — the relative loss tolerance (`tol`) cannot. Ignored by the
    /// in-memory [`CoxFit::fit`].
    pub fn stop_kkt(mut self, eps: f64) -> Self {
        self.stop_kkt = eps;
        self
    }

    /// Wall-clock budget in seconds (0 = unlimited); exhaustion is
    /// reported on `FitDiagnostics::budget_exhausted`.
    pub fn budget_secs(mut self, secs: f64) -> Self {
        self.budget_secs = secs;
        self
    }

    /// Record the per-iteration loss trace (on by default).
    pub fn record_trace(mut self, record: bool) -> Self {
        self.record_trace = record;
        self
    }

    /// Kernel backend / thread-count / storage-precision request (see
    /// [`Compute`]). Resolved exactly once when the fit starts; an
    /// unknown backend or an invalid thread count surfaces as a typed
    /// error from that resolution, never a silent fallback. Under
    /// [`Precision::F32Storage`] every feature cell is rounded through
    /// f32 before the problem is built (f64 accumulation throughout),
    /// matching what a v2 `.fsds` store serves — coefficients agree
    /// with the f64 fit to ≤1e-6.
    pub fn compute(mut self, compute: Compute) -> Self {
        self.compute = compute;
        self
    }

    /// Number of λ grid points for [`CoxFit::l1_path`] (default 50).
    pub fn n_lambdas(mut self, n: usize) -> Self {
        self.n_lambdas = n;
        self
    }

    /// λ_min / λ_max ratio of the path grid (default 0.01).
    pub fn lambda_min_ratio(mut self, r: f64) -> Self {
        self.lambda_min_ratio = r;
        self
    }

    /// ElasticNet mixing for [`CoxFit::l1_path`]: the per-point penalty
    /// is λ·(l1_ratio·‖β‖₁ + (1−l1_ratio)·‖β‖₂²). Default 1.0 (lasso).
    pub fn l1_ratio(mut self, r: f64) -> Self {
        self.l1_ratio = r;
        self
    }

    fn validate(&self, ds: &SurvivalDataset) -> Result<()> {
        if !self.l1.is_finite() || self.l1 < 0.0 || !self.l2.is_finite() || self.l2 < 0.0 {
            return Err(FastSurvivalError::InvalidConfig(format!(
                "penalties must be finite and non-negative (got l1={}, l2={})",
                self.l1, self.l2
            )));
        }
        if self.max_iters == 0 {
            return Err(FastSurvivalError::InvalidConfig(
                "max_iters must be at least 1".into(),
            ));
        }
        if !self.tol.is_finite() || self.tol < 0.0 {
            return Err(FastSurvivalError::InvalidConfig(format!(
                "tol must be finite and non-negative (got {})",
                self.tol
            )));
        }
        if self.l1 > 0.0 && !self.optimizer.supports_l1() {
            return Err(FastSurvivalError::InvalidConfig(format!(
                "optimizer {:?} does not support ℓ1 (non-smooth) objectives; \
                 use quadratic/cubic/quasi-newton/prox-newton/gd",
                self.optimizer.name()
            )));
        }
        if self.engine != EngineKind::Native && !self.optimizer.engine_generic() {
            return Err(FastSurvivalError::Unsupported(format!(
                "optimizer {:?} runs on the native engine only; the quadratic and \
                 cubic surrogates are engine-generic",
                self.optimizer.name()
            )));
        }
        if ds.p() == 0 {
            return Err(FastSurvivalError::InvalidData(
                "dataset has no feature columns".into(),
            ));
        }
        if ds.n() > 0 && ds.n_events() == 0 {
            return Err(FastSurvivalError::InvalidData(
                "all samples are censored: the Cox partial likelihood has no events \
                 to fit".into(),
            ));
        }
        Ok(())
    }

    /// Validate, preprocess, fit, and package the result. Invalid data
    /// or configuration, an unavailable engine, and optimizer divergence
    /// all surface as typed errors instead of panics.
    pub fn fit(&self, ds: &SurvivalDataset) -> Result<CoxModel> {
        self.validate(ds)?;
        let rc = self.compute.resolve()?;
        let ds = dataset_for(ds, rc.precision);
        let ds = ds.as_ref();
        let problem = CoxProblem::try_new(ds)?;
        let engine: Box<dyn CoxEngine> = self.engine.build(&self.artifact_dir)?;
        let optimizer: Box<dyn Optimizer> = self.optimizer.build();
        let config = FitConfig {
            objective: Objective { l1: self.l1, l2: self.l2 },
            max_iters: self.max_iters,
            tol: self.tol,
            budget_secs: self.budget_secs,
            record_trace: self.record_trace,
            compute: rc,
        };

        let obs_before = obs_snapshot();
        let t0 = Instant::now();
        let state = CoxState::zeros(&problem);
        let res = optimizer.fit_from(&problem, state, &config, engine.as_ref())?;
        let wall_secs = t0.elapsed().as_secs_f64();
        if res.trace.diverged {
            return Err(FastSurvivalError::Diverged {
                optimizer: optimizer.name().to_string(),
                iterations: res.iterations,
            });
        }

        // Baseline hazard from the training linear predictors.
        let eta = ds.x.matvec(&res.beta);
        let baseline = BreslowBaseline::fit(&ds.time, &ds.event, &eta);

        let diagnostics = FitDiagnostics {
            optimizer: optimizer.name().to_string(),
            engine: engine.name().to_string(),
            iterations: res.iterations,
            converged: res.trace.converged,
            budget_exhausted: res.trace.budget_exhausted,
            objective_value: res.objective_value,
            l1: self.l1,
            l2: self.l2,
            n_train: ds.n(),
            n_events: ds.n_events(),
            wall_secs,
            trace: res.trace,
            report: capture_report(&obs_before),
        };
        Ok(CoxModel::from_parts(
            ds.feature_names.clone(),
            res.beta,
            baseline,
            diagnostics,
        ))
    }

    // --------------------------------------------- out-of-core fitting

    /// Fit from an on-disk `.fsds` columnar store (see [`crate::store`])
    /// without ever materializing the design matrix: sampled-block
    /// surrogate warmup, then exact chunked surrogate coordinate descent
    /// streaming one column per step. Builder knobs carry over where
    /// they apply (`l1`/`l2`, `max_iters` as full-data sweeps, `tol`,
    /// `stop_kkt`, `budget_secs`); the optimizer must be a surrogate
    /// (quadratic|cubic) and the engine native. Chunked and in-memory
    /// runs of the same streamed algorithm match bit for bit; with
    /// [`CoxFit::stop_kkt`] armed (e.g. 1e-9) the result also matches
    /// an independently-run [`CoxFit::fit`]-style in-memory solve to
    /// ≤1e-8 — the default loss tolerance alone does not certify that
    /// bound, only coarse agreement.
    pub fn fit_store(&self, store_path: impl AsRef<Path>) -> Result<CoxModel> {
        let surrogate = match self.optimizer {
            OptimizerKind::Quadratic => SurrogateKind::Quadratic,
            OptimizerKind::Cubic => SurrogateKind::Cubic,
            other => {
                return Err(FastSurvivalError::InvalidConfig(format!(
                    "out-of-core fitting needs a surrogate CD optimizer (quadratic|cubic), \
                     got {:?}",
                    other.name()
                )))
            }
        };
        if self.engine != EngineKind::Native {
            return Err(FastSurvivalError::Unsupported(
                "out-of-core fitting runs on the native engine only (the chunked column \
                 sweep is an in-process hot path)"
                    .into(),
            ));
        }
        if !self.tol.is_finite() || self.tol < 0.0 {
            return Err(FastSurvivalError::InvalidConfig(format!(
                "tol must be finite and non-negative (got {})",
                self.tol
            )));
        }
        if !self.stop_kkt.is_finite() || self.stop_kkt < 0.0 {
            return Err(FastSurvivalError::InvalidConfig(format!(
                "stop_kkt must be finite and non-negative (got {})",
                self.stop_kkt
            )));
        }
        let mut data = ChunkedDataset::open(store_path.as_ref())?;
        // Note: the *storage* precision of a `.fsds` fit is fixed by the
        // store's header (set at conversion time); `compute.precision`
        // only affects in-memory fits. Backend and threads apply here.
        let fitter = StreamingFit {
            objective: Objective { l1: self.l1, l2: self.l2 },
            surrogate,
            max_sweeps: self.max_iters,
            tol: self.tol,
            stop_kkt: self.stop_kkt,
            budget_secs: self.budget_secs,
            compute: self.compute,
            ..Default::default()
        };
        let obs_before = obs_snapshot();
        let t0 = Instant::now();
        let res = fitter.fit(&mut data)?;
        let wall_secs = t0.elapsed().as_secs_f64();
        if res.trace.diverged {
            return Err(FastSurvivalError::Diverged {
                optimizer: format!("streaming-{}", surrogate.name()),
                iterations: res.sweeps,
            });
        }
        let meta = data.meta();
        // Baseline from the sorted training order — BreslowBaseline::fit
        // is order-agnostic, and the streamed fit hands back η aligned
        // with the store's sorted time/event columns.
        let baseline = BreslowBaseline::fit(&meta.time, &meta.event, &res.eta);
        let diagnostics = FitDiagnostics {
            optimizer: format!("streaming-{}", surrogate.name()),
            engine: "chunked-store".to_string(),
            iterations: res.sweeps,
            converged: res.trace.converged,
            budget_exhausted: res.trace.budget_exhausted,
            objective_value: res.objective_value,
            l1: self.l1,
            l2: self.l2,
            n_train: meta.n,
            n_events: meta.n_events,
            wall_secs,
            trace: res.trace,
            report: capture_report(&obs_before),
        };
        Ok(CoxModel::from_parts(
            meta.feature_names.clone(),
            res.beta,
            baseline,
            diagnostics,
        ))
    }

    // ---------------------------------------------------- path fitting

    /// Common validation for path fits: paths run the surrogate CD hot
    /// path on the native engine only, and derive their penalties from
    /// the λ grid — explicit `.l1()`/`.l2()` settings would be silently
    /// discarded, so they are rejected instead.
    fn validate_path(&self, ds: &SurvivalDataset) -> Result<SurrogateKind> {
        self.validate(ds)?;
        if self.l1 != 0.0 || self.l2 != 0.0 {
            return Err(FastSurvivalError::InvalidConfig(format!(
                "path fitting derives penalties from the λ grid; explicit .l1({})/.l2({}) \
                 settings do not apply (use .l1_ratio()/.lambda_min_ratio()/.n_lambdas() \
                 to shape the grid, or .fit() for a single penalized model)",
                self.l1, self.l2
            )));
        }
        if self.engine != EngineKind::Native {
            return Err(FastSurvivalError::Unsupported(
                "path fitting runs on the native engine only (the screened \
                 active-set loop is an in-process hot path)"
                    .into(),
            ));
        }
        match self.optimizer {
            OptimizerKind::Quadratic => Ok(SurrogateKind::Quadratic),
            OptimizerKind::Cubic => Ok(SurrogateKind::Cubic),
            other => Err(FastSurvivalError::InvalidConfig(format!(
                "path fitting needs a surrogate CD optimizer (quadratic|cubic), got {:?}",
                other.name()
            ))),
        }
    }

    /// Fit the whole ℓ1(+ℓ2) regularization path: a log-spaced λ grid
    /// from the data-derived λ_max, warm starts between grid points,
    /// sequential strong-rule screening, and a full KKT check per point.
    /// Penalties come from the grid — `.l1()`/`.l2()` must stay unset
    /// (rejected otherwise), and `.tol()`/`.budget_secs()` do not apply
    /// (the path's inner stopping is KKT-residual-based).
    /// Returns a [`CoxPath`] whose every point materializes as a
    /// [`CoxModel`].
    pub fn l1_path(&self, ds: &SurvivalDataset) -> Result<CoxPath> {
        let surrogate = self.validate_path(ds)?;
        let rc = self.compute.resolve()?;
        let ds = dataset_for(ds, rc.precision);
        let ds = ds.as_ref();
        let problem = CoxProblem::try_new(ds)?;
        // Note: `tol` (the loss-change tolerance of single fits) does not
        // apply here — the path's inner stopping is KKT-residual-based
        // (PathSolver::stop_rel), which is what certifies warm/cold parity.
        let solver = PathSolver {
            n_lambdas: self.n_lambdas,
            min_ratio: self.lambda_min_ratio,
            l1_ratio: self.l1_ratio,
            surrogate,
            max_sweeps: self.max_iters,
            backend: rc.backend,
            ..Default::default()
        };
        let obs_before = obs_snapshot();
        let t0 = Instant::now();
        let path = solver.run(&problem)?;
        let wall_secs = t0.elapsed().as_secs_f64();
        let points = path
            .points
            .into_iter()
            .map(|pt| {
                let eta = ds.x.matvec(&pt.beta);
                let baseline = BreslowBaseline::fit(&ds.time, &ds.event, &eta);
                CoxPathPoint {
                    lambda: Some(pt.lambda),
                    k: pt.support.len(),
                    l1: pt.l1,
                    l2: pt.l2,
                    beta: pt.beta,
                    train_loss: pt.train_loss,
                    iterations: pt.sweeps,
                    baseline,
                }
            })
            .collect();
        let mut out = CoxPath::from_parts(
            PathKind::L1,
            ds.feature_names.clone(),
            points,
            surrogate.name().to_string(),
            ds.n(),
            ds.n_events(),
            wall_secs,
        );
        out.set_report(capture_report(&obs_before));
        Ok(out)
    }

    /// Fit the cardinality path k = 1..=`max_k` with the paper's beam
    /// search (each level warm-extends the previous one). Returns a
    /// [`CoxPath`] queryable per support size.
    pub fn cardinality_path(&self, ds: &SurvivalDataset, max_k: usize) -> Result<CoxPath> {
        self.cardinality_path_with(
            ds,
            max_k,
            &CardinalitySolver::Beam(BeamSearch::default()),
        )
    }

    /// [`CoxFit::cardinality_path`] with an explicit k-path engine (beam
    /// search or warm-chained ABESS).
    pub fn cardinality_path_with(
        &self,
        ds: &SurvivalDataset,
        max_k: usize,
        solver: &CardinalitySolver,
    ) -> Result<CoxPath> {
        self.validate_path(ds)?;
        if max_k == 0 {
            return Err(FastSurvivalError::InvalidConfig(
                "cardinality path needs max_k >= 1".into(),
            ));
        }
        let rc = self.compute.resolve()?;
        let ds = dataset_for(ds, rc.precision);
        let ds = ds.as_ref();
        let problem = CoxProblem::try_new(ds)?;
        let obs_before = obs_snapshot();
        let t0 = Instant::now();
        let path: CardinalityPath = solver.run(&problem, max_k);
        let wall_secs = t0.elapsed().as_secs_f64();
        if path.is_empty() {
            return Err(FastSurvivalError::InvalidData(
                "cardinality path came back empty (no support size was reachable)".into(),
            ));
        }
        let points = path
            .points
            .into_iter()
            .map(|pt| {
                let eta = ds.x.matvec(&pt.beta);
                let baseline = BreslowBaseline::fit(&ds.time, &ds.event, &eta);
                CoxPathPoint {
                    lambda: None,
                    k: pt.k,
                    l1: 0.0,
                    l2: 0.0,
                    beta: pt.beta,
                    train_loss: pt.train_loss,
                    iterations: 0,
                    baseline,
                }
            })
            .collect();
        let mut out = CoxPath::from_parts(
            PathKind::Cardinality,
            ds.feature_names.clone(),
            points,
            solver.name().to_string(),
            ds.n(),
            ds.n_events(),
            wall_secs,
        );
        out.set_report(capture_report(&obs_before));
        Ok(out)
    }
}

/// Diff the observability sink against a pre-fit snapshot: `Some` only
/// when tracing was enabled and the fit actually recorded spans or
/// counters, so untraced runs serialize `"report": null` unchanged.
fn capture_report(before: &ObsSnapshot) -> Option<FitReport> {
    let report = FitReport::capture_since(before);
    if report.is_empty() {
        None
    } else {
        Some(report)
    }
}

/// The dataset a fit actually runs on: under [`Precision::F32Storage`]
/// every feature cell is rounded through f32 first, so the in-memory
/// engines compute on exactly the values a v2 `.fsds` store of the same
/// data would serve. Times and events stay f64/bool untouched.
fn dataset_for(ds: &SurvivalDataset, precision: Precision) -> Cow<'_, SurvivalDataset> {
    match precision {
        Precision::F64 => Cow::Borrowed(ds),
        Precision::F32Storage => {
            let mut q = ds.clone();
            q.x.quantize_f32();
            Cow::Owned(q)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::linalg::Matrix;
    use crate::util::compute::Backend;

    fn ds() -> SurvivalDataset {
        generate(&SyntheticConfig { n: 200, p: 10, rho: 0.4, k: 3, s: 0.1, seed: 11 })
    }

    #[test]
    fn kind_names_round_trip() {
        for k in OptimizerKind::ALL {
            assert_eq!(OptimizerKind::from_name(k.name()).unwrap(), k);
        }
        assert!(OptimizerKind::from_name("sgd").is_err());
        assert_eq!(EngineKind::from_name("native").unwrap(), EngineKind::Native);
        assert_eq!(EngineKind::from_name("xla").unwrap(), EngineKind::Xla);
        assert!(EngineKind::from_name("tpu").is_err());
    }

    #[test]
    fn default_fit_produces_informative_model() {
        let ds = ds();
        let model = CoxFit::new().l2(0.1).fit(&ds).unwrap();
        assert_eq!(model.p(), ds.p());
        let ci = model.concordance(&ds).unwrap();
        assert!(ci > 0.6, "cindex {ci}");
        let d = model.diagnostics();
        assert_eq!(d.engine, "native");
        assert_eq!(d.optimizer, "cubic-surrogate");
        assert!(d.iterations > 0);
        assert!(!d.budget_exhausted);
    }

    #[test]
    fn every_optimizer_kind_fits_through_the_builder() {
        // Strong ridge keeps the Newton-family baselines convergent so
        // every kind exercises the same one builder path.
        let ds = ds();
        for k in OptimizerKind::ALL {
            let model = CoxFit::new().l2(5.0).optimizer(k).max_iters(30).fit(&ds).unwrap();
            assert!(
                model.beta().iter().all(|b| b.is_finite()),
                "{:?} produced non-finite beta",
                k
            );
        }
    }

    #[test]
    fn invalid_configs_are_rejected() {
        let ds = ds();
        assert!(matches!(
            CoxFit::new().l1(-1.0).fit(&ds),
            Err(FastSurvivalError::InvalidConfig(_))
        ));
        assert!(matches!(
            CoxFit::new().l2(f64::NAN).fit(&ds),
            Err(FastSurvivalError::InvalidConfig(_))
        ));
        assert!(matches!(
            CoxFit::new().max_iters(0).fit(&ds),
            Err(FastSurvivalError::InvalidConfig(_))
        ));
        assert!(matches!(
            CoxFit::new().l1(1.0).optimizer(OptimizerKind::Newton).fit(&ds),
            Err(FastSurvivalError::InvalidConfig(_))
        ));
        assert!(matches!(
            CoxFit::new().optimizer(OptimizerKind::Newton).engine(EngineKind::Xla).fit(&ds),
            Err(FastSurvivalError::Unsupported(_))
        ));
    }

    #[test]
    fn compute_request_is_resolved_once_with_typed_errors() {
        let ds = ds();
        // Invalid thread count is a typed config error, not a panic.
        assert!(matches!(
            CoxFit::new().l2(0.1).compute(Compute::default().threads(0)).fit(&ds),
            Err(FastSurvivalError::InvalidConfig(_))
        ));
        // Explicit scalar and SIMD requests both fit and agree closely
        // (the fit-level tolerance absorbs reassociated reductions).
        let scalar = CoxFit::new()
            .l2(0.1)
            .compute(Compute::default().backend(Backend::Scalar))
            .fit(&ds)
            .unwrap();
        let simd = CoxFit::new()
            .l2(0.1)
            .compute(Compute::default().backend(Backend::Simd))
            .fit(&ds)
            .unwrap();
        for (a, b) in scalar.beta().iter().zip(simd.beta().iter()) {
            assert!((a - b).abs() <= 1e-8, "scalar {a} vs simd {b}");
        }
    }

    #[test]
    fn f32_storage_fit_matches_f64_to_1e6() {
        let ds = ds();
        let full = CoxFit::new().l2(0.5).fit(&ds).unwrap();
        let mixed = CoxFit::new()
            .l2(0.5)
            .compute(Compute::default().precision(Precision::F32Storage))
            .fit(&ds)
            .unwrap();
        for (a, b) in full.beta().iter().zip(mixed.beta().iter()) {
            assert!((a - b).abs() <= 1e-6, "f64 {a} vs f32-storage {b}");
        }
    }

    #[test]
    fn all_censored_dataset_is_a_typed_error() {
        let x = Matrix::from_columns(&[vec![1.0, -1.0, 0.5]]);
        let d = SurvivalDataset::new(x, vec![3.0, 2.0, 1.0], vec![false; 3], "censored");
        assert!(matches!(
            CoxFit::new().fit(&d),
            Err(FastSurvivalError::InvalidData(_))
        ));
    }

    #[test]
    fn l1_path_through_the_builder() {
        let ds = ds();
        let path = CoxFit::new().n_lambdas(12).l1_path(&ds).unwrap();
        assert_eq!(path.len(), 12);
        assert_eq!(path.kind(), crate::api::PathKind::L1);
        // λ_max endpoint is the empty model; λ_min is not.
        assert_eq!(path.points()[0].k, 0);
        assert!(path.points().last().unwrap().k > 0);
        // Every point materializes as a predicting model.
        let m = path.model_at(path.len() - 1).unwrap();
        assert!(m.concordance(&ds).unwrap() > 0.55);
        // Closest-λ lookup hits the endpoint for λ → 0.
        let end = path.model_for_lambda(0.0).unwrap();
        assert_eq!(end.beta(), m.beta());
    }

    #[test]
    fn cardinality_path_through_the_builder() {
        let ds = ds();
        let path = CoxFit::new().cardinality_path(&ds, 4).unwrap();
        assert_eq!(path.kind(), crate::api::PathKind::Cardinality);
        assert!(!path.is_empty());
        let m = path.model_for_k(3).unwrap();
        assert_eq!(m.beta().iter().filter(|b| b.abs() > 1e-10).count(), 3);
    }

    #[test]
    fn path_rejects_non_surrogate_or_non_native_configs() {
        let ds = ds();
        assert!(matches!(
            CoxFit::new().optimizer(OptimizerKind::Newton).l1_path(&ds),
            Err(FastSurvivalError::InvalidConfig(_))
        ));
        // Explicit penalties would be silently discarded by a path fit.
        assert!(matches!(
            CoxFit::new().l1(0.5).l1_path(&ds),
            Err(FastSurvivalError::InvalidConfig(_))
        ));
        assert!(matches!(
            CoxFit::new().l2(0.1).cardinality_path(&ds, 3),
            Err(FastSurvivalError::InvalidConfig(_))
        ));
        assert!(matches!(
            CoxFit::new().engine(EngineKind::Xla).l1_path(&ds),
            Err(FastSurvivalError::Unsupported(_))
        ));
        assert!(CoxFit::new().cardinality_path(&ds, 0).is_err());
    }

    #[test]
    fn budget_exhaustion_is_reported_on_diagnostics() {
        // A generous problem with a vanishing budget: the fit must stop
        // early and say why.
        let ds = generate(&SyntheticConfig { n: 400, p: 40, rho: 0.5, k: 5, s: 0.1, seed: 3 });
        let model = CoxFit::new()
            .l2(0.5)
            .max_iters(100_000)
            .tol(0.0)
            .budget_secs(1e-6)
            .fit(&ds)
            .unwrap();
        let d = model.diagnostics();
        assert!(d.budget_exhausted, "budget flag must be set");
        assert!(!d.converged);
        assert!(d.iterations < 100_000);
    }
}
