//! The `CoxFit` builder: one fluent entry point that assembles the
//! problem, the compute engine, and the optimizer, fits, and returns a
//! [`CoxModel`].
//!
//! ```no_run
//! use fastsurvival::api::{CoxFit, EngineKind, OptimizerKind};
//! # let ds = fastsurvival::data::synthetic::generate(&Default::default());
//! let model = CoxFit::new()
//!     .l1(0.5)
//!     .l2(0.1)
//!     .optimizer(OptimizerKind::Cubic)
//!     .engine(EngineKind::Native)
//!     .max_iters(200)
//!     .fit(&ds)?;
//! let risk = model.predict_risk(&ds.x)?;
//! # Ok::<(), fastsurvival::error::FastSurvivalError>(())
//! ```

use super::model::{CoxModel, FitDiagnostics};
use crate::cox::{CoxProblem, CoxState};
use crate::data::SurvivalDataset;
use crate::error::{FastSurvivalError, Result};
use crate::metrics::BreslowBaseline;
use crate::optim::{FitConfig, Objective, Optimizer};
use crate::runtime::engine::CoxEngine;
use std::path::PathBuf;
use std::time::Instant;

// The typed registries live with the layers they enumerate; the api
// module re-exports them as part of the stable surface.
pub use crate::optim::OptimizerKind;
pub use crate::runtime::engine::EngineKind;

/// Fluent builder for fitting a Cox proportional hazards model.
///
/// Defaults: cubic surrogate, native engine, no regularization,
/// `max_iters = 200`, `tol = 1e-9`, unlimited wall clock.
#[derive(Clone, Debug)]
pub struct CoxFit {
    l1: f64,
    l2: f64,
    optimizer: OptimizerKind,
    engine: EngineKind,
    artifact_dir: PathBuf,
    max_iters: usize,
    tol: f64,
    budget_secs: f64,
    record_trace: bool,
}

impl Default for CoxFit {
    fn default() -> Self {
        CoxFit {
            l1: 0.0,
            l2: 0.0,
            optimizer: OptimizerKind::Cubic,
            engine: EngineKind::Native,
            artifact_dir: PathBuf::from("artifacts"),
            max_iters: 200,
            tol: 1e-9,
            budget_secs: 0.0,
            record_trace: true,
        }
    }
}

impl CoxFit {
    pub fn new() -> Self {
        Self::default()
    }

    /// ℓ1 (lasso) penalty weight λ1 ≥ 0.
    pub fn l1(mut self, l1: f64) -> Self {
        self.l1 = l1;
        self
    }

    /// ℓ2 (ridge) penalty weight λ2 ≥ 0.
    pub fn l2(mut self, l2: f64) -> Self {
        self.l2 = l2;
        self
    }

    pub fn optimizer(mut self, kind: OptimizerKind) -> Self {
        self.optimizer = kind;
        self
    }

    pub fn engine(mut self, kind: EngineKind) -> Self {
        self.engine = kind;
        self
    }

    /// Directory holding the AOT artifacts (`manifest.tsv`) for
    /// [`EngineKind::Xla`].
    pub fn artifact_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifact_dir = dir.into();
        self
    }

    /// Maximum outer iterations (CD sweeps / Newton steps).
    pub fn max_iters(mut self, iters: usize) -> Self {
        self.max_iters = iters;
        self
    }

    /// Relative loss-decrease convergence tolerance.
    pub fn tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    /// Wall-clock budget in seconds (0 = unlimited); exhaustion is
    /// reported on `FitDiagnostics::budget_exhausted`.
    pub fn budget_secs(mut self, secs: f64) -> Self {
        self.budget_secs = secs;
        self
    }

    /// Record the per-iteration loss trace (on by default).
    pub fn record_trace(mut self, record: bool) -> Self {
        self.record_trace = record;
        self
    }

    fn validate(&self, ds: &SurvivalDataset) -> Result<()> {
        if !self.l1.is_finite() || self.l1 < 0.0 || !self.l2.is_finite() || self.l2 < 0.0 {
            return Err(FastSurvivalError::InvalidConfig(format!(
                "penalties must be finite and non-negative (got l1={}, l2={})",
                self.l1, self.l2
            )));
        }
        if self.max_iters == 0 {
            return Err(FastSurvivalError::InvalidConfig(
                "max_iters must be at least 1".into(),
            ));
        }
        if !self.tol.is_finite() || self.tol < 0.0 {
            return Err(FastSurvivalError::InvalidConfig(format!(
                "tol must be finite and non-negative (got {})",
                self.tol
            )));
        }
        if self.l1 > 0.0 && !self.optimizer.supports_l1() {
            return Err(FastSurvivalError::InvalidConfig(format!(
                "optimizer {:?} does not support ℓ1 (non-smooth) objectives; \
                 use quadratic/cubic/quasi-newton/prox-newton/gd",
                self.optimizer.name()
            )));
        }
        if self.engine != EngineKind::Native && !self.optimizer.engine_generic() {
            return Err(FastSurvivalError::Unsupported(format!(
                "optimizer {:?} runs on the native engine only; the quadratic and \
                 cubic surrogates are engine-generic",
                self.optimizer.name()
            )));
        }
        if ds.p() == 0 {
            return Err(FastSurvivalError::InvalidData(
                "dataset has no feature columns".into(),
            ));
        }
        if ds.n() > 0 && ds.n_events() == 0 {
            return Err(FastSurvivalError::InvalidData(
                "all samples are censored: the Cox partial likelihood has no events \
                 to fit".into(),
            ));
        }
        Ok(())
    }

    /// Validate, preprocess, fit, and package the result. Invalid data
    /// or configuration, an unavailable engine, and optimizer divergence
    /// all surface as typed errors instead of panics.
    pub fn fit(&self, ds: &SurvivalDataset) -> Result<CoxModel> {
        self.validate(ds)?;
        let problem = CoxProblem::try_new(ds)?;
        let engine: Box<dyn CoxEngine> = self.engine.build(&self.artifact_dir)?;
        let optimizer: Box<dyn Optimizer> = self.optimizer.build();
        let config = FitConfig {
            objective: Objective { l1: self.l1, l2: self.l2 },
            max_iters: self.max_iters,
            tol: self.tol,
            budget_secs: self.budget_secs,
            record_trace: self.record_trace,
        };

        let t0 = Instant::now();
        let state = CoxState::zeros(&problem);
        let res = optimizer.fit_from(&problem, state, &config, engine.as_ref())?;
        let wall_secs = t0.elapsed().as_secs_f64();
        if res.trace.diverged {
            return Err(FastSurvivalError::Diverged {
                optimizer: optimizer.name().to_string(),
                iterations: res.iterations,
            });
        }

        // Baseline hazard from the training linear predictors.
        let eta = ds.x.matvec(&res.beta);
        let baseline = BreslowBaseline::fit(&ds.time, &ds.event, &eta);

        let diagnostics = FitDiagnostics {
            optimizer: optimizer.name().to_string(),
            engine: engine.name().to_string(),
            iterations: res.iterations,
            converged: res.trace.converged,
            budget_exhausted: res.trace.budget_exhausted,
            objective_value: res.objective_value,
            l1: self.l1,
            l2: self.l2,
            n_train: ds.n(),
            n_events: ds.n_events(),
            wall_secs,
            trace: res.trace,
        };
        Ok(CoxModel::from_parts(
            ds.feature_names.clone(),
            res.beta,
            baseline,
            diagnostics,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::linalg::Matrix;

    fn ds() -> SurvivalDataset {
        generate(&SyntheticConfig { n: 200, p: 10, rho: 0.4, k: 3, s: 0.1, seed: 11 })
    }

    #[test]
    fn kind_names_round_trip() {
        for k in OptimizerKind::ALL {
            assert_eq!(OptimizerKind::from_name(k.name()).unwrap(), k);
        }
        assert!(OptimizerKind::from_name("sgd").is_err());
        assert_eq!(EngineKind::from_name("native").unwrap(), EngineKind::Native);
        assert_eq!(EngineKind::from_name("xla").unwrap(), EngineKind::Xla);
        assert!(EngineKind::from_name("tpu").is_err());
    }

    #[test]
    fn default_fit_produces_informative_model() {
        let ds = ds();
        let model = CoxFit::new().l2(0.1).fit(&ds).unwrap();
        assert_eq!(model.p(), ds.p());
        let ci = model.concordance(&ds).unwrap();
        assert!(ci > 0.6, "cindex {ci}");
        let d = model.diagnostics();
        assert_eq!(d.engine, "native");
        assert_eq!(d.optimizer, "cubic-surrogate");
        assert!(d.iterations > 0);
        assert!(!d.budget_exhausted);
    }

    #[test]
    fn every_optimizer_kind_fits_through_the_builder() {
        // Strong ridge keeps the Newton-family baselines convergent so
        // every kind exercises the same one builder path.
        let ds = ds();
        for k in OptimizerKind::ALL {
            let model = CoxFit::new().l2(5.0).optimizer(k).max_iters(30).fit(&ds).unwrap();
            assert!(
                model.beta().iter().all(|b| b.is_finite()),
                "{:?} produced non-finite beta",
                k
            );
        }
    }

    #[test]
    fn invalid_configs_are_rejected() {
        let ds = ds();
        assert!(matches!(
            CoxFit::new().l1(-1.0).fit(&ds),
            Err(FastSurvivalError::InvalidConfig(_))
        ));
        assert!(matches!(
            CoxFit::new().l2(f64::NAN).fit(&ds),
            Err(FastSurvivalError::InvalidConfig(_))
        ));
        assert!(matches!(
            CoxFit::new().max_iters(0).fit(&ds),
            Err(FastSurvivalError::InvalidConfig(_))
        ));
        assert!(matches!(
            CoxFit::new().l1(1.0).optimizer(OptimizerKind::Newton).fit(&ds),
            Err(FastSurvivalError::InvalidConfig(_))
        ));
        assert!(matches!(
            CoxFit::new().optimizer(OptimizerKind::Newton).engine(EngineKind::Xla).fit(&ds),
            Err(FastSurvivalError::Unsupported(_))
        ));
    }

    #[test]
    fn all_censored_dataset_is_a_typed_error() {
        let x = Matrix::from_columns(&[vec![1.0, -1.0, 0.5]]);
        let d = SurvivalDataset::new(x, vec![3.0, 2.0, 1.0], vec![false; 3], "censored");
        assert!(matches!(
            CoxFit::new().fit(&d),
            Err(FastSurvivalError::InvalidData(_))
        ));
    }

    #[test]
    fn budget_exhaustion_is_reported_on_diagnostics() {
        // A generous problem with a vanishing budget: the fit must stop
        // early and say why.
        let ds = generate(&SyntheticConfig { n: 400, p: 40, rho: 0.5, k: 5, s: 0.1, seed: 3 });
        let model = CoxFit::new()
            .l2(0.5)
            .max_iters(100_000)
            .tol(0.0)
            .budget_secs(1e-6)
            .fit(&ds)
            .unwrap();
        let d = model.diagnostics();
        assert!(d.budget_exhausted, "budget flag must be set");
        assert!(!d.converged);
        assert!(d.iterations < 100_000);
    }
}
