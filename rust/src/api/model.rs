//! The fitted estimator returned by [`crate::api::CoxFit`]: coefficients,
//! the fitted Breslow baseline, fit diagnostics, prediction, evaluation,
//! and JSON persistence.

use super::json::{self, Json};
use crate::data::SurvivalDataset;
use crate::error::{FastSurvivalError, Result};
use crate::linalg::Matrix;
use crate::metrics::{concordance_index, BreslowBaseline};
use crate::obs::FitReport;
use crate::optim::objective::TracePoint;
use crate::optim::Trace;
use std::path::Path;

/// Version tag written into saved model files.
const FORMAT_VERSION: usize = 1;

/// What happened during the fit, preserved on the model.
#[derive(Clone, Debug)]
pub struct FitDiagnostics {
    /// Optimizer display name (e.g. "cubic-surrogate").
    pub optimizer: String,
    /// Engine display name ("native" or "xla").
    pub engine: String,
    /// Outer iterations (CD sweeps / Newton steps) actually run.
    pub iterations: usize,
    /// Relative-tolerance convergence reached.
    pub converged: bool,
    /// The fit stopped because the wall-clock budget ran out — distinct
    /// from convergence (see `Trace::budget_exhausted`).
    pub budget_exhausted: bool,
    /// Final penalized objective value.
    pub objective_value: f64,
    /// Penalties the model was trained with.
    pub l1: f64,
    pub l2: f64,
    /// Training-set shape.
    pub n_train: usize,
    pub n_events: usize,
    /// Wall-clock fit time in seconds.
    pub wall_secs: f64,
    /// Full loss history with per-point sweep counts and KKT residuals.
    /// Persisted in the saved JSON (models saved by older builds load
    /// with an empty trace).
    pub trace: Trace,
    /// Observability report for the fit: per-phase span timings and
    /// engine counters, captured only when tracing was enabled
    /// ([`crate::obs::set_enabled`]). Persisted when present.
    pub report: Option<FitReport>,
}

/// Serialize a loss trace — shared by the model and path documents.
pub(crate) fn write_trace_json(out: &mut String, t: &Trace) {
    out.push_str("{\"diverged\": ");
    out.push_str(if t.diverged { "true" } else { "false" });
    out.push_str(", \"converged\": ");
    out.push_str(if t.converged { "true" } else { "false" });
    out.push_str(", \"budget_exhausted\": ");
    out.push_str(if t.budget_exhausted { "true" } else { "false" });
    out.push_str(", \"points\": [");
    for (i, pt) in t.points.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&format!("{{\"iter\": {}, \"secs\": ", pt.iter));
        json::write_f64(out, pt.secs);
        out.push_str(", \"loss\": ");
        json::write_f64(out, pt.loss);
        out.push_str(&format!(", \"sweeps\": {}, \"kkt\": ", pt.sweeps));
        match pt.kkt {
            Some(v) => json::write_f64(out, v),
            None => out.push_str("null"),
        }
        out.push('}');
    }
    out.push_str("]}");
}

/// Rebuild a loss trace from [`write_trace_json`] output.
pub(crate) fn trace_from_json(v: &Json) -> Result<Trace> {
    let points_json = v.require("points")?.as_array()?;
    let mut points = Vec::with_capacity(points_json.len());
    for pt in points_json {
        points.push(TracePoint {
            iter: pt.require("iter")?.as_usize()?,
            secs: pt.require("secs")?.as_f64()?,
            loss: pt.require("loss")?.as_f64()?,
            sweeps: pt.require("sweeps")?.as_usize()?,
            kkt: match pt.require("kkt")? {
                Json::Null => None,
                other => Some(other.as_f64()?),
            },
        });
    }
    Ok(Trace {
        points,
        diverged: v.require("diverged")?.as_bool()?,
        converged: v.require("converged")?.as_bool()?,
        budget_exhausted: v.require("budget_exhausted")?.as_bool()?,
    })
}

/// Write an optional fit report as its JSON object or `null`.
pub(crate) fn write_report_field(out: &mut String, report: &Option<FitReport>) {
    match report {
        Some(r) => r.write_json(out),
        None => out.push_str("null"),
    }
}

/// Read the optional `report` field of a diagnostics object — absent
/// (older files) and `null` both load as `None`.
pub(crate) fn report_from_json(d: &Json) -> Result<Option<FitReport>> {
    match d.get("report") {
        None | Some(Json::Null) => Ok(None),
        Some(v) => Ok(Some(FitReport::from_json(v)?)),
    }
}

/// One coefficient keyed by its original feature index and name — the
/// documented replacement for the old no-op `CoxProblem::beta_to_original`
/// (feature columns are never permuted by preprocessing, so the index is
/// the dataset's own column index).
#[derive(Clone, Debug, PartialEq)]
pub struct Coefficient {
    pub index: usize,
    pub name: String,
    pub value: f64,
}

/// A fitted Cox proportional hazards model.
#[derive(Clone, Debug)]
pub struct CoxModel {
    feature_names: Vec<String>,
    beta: Vec<f64>,
    baseline: BreslowBaseline,
    diagnostics: FitDiagnostics,
}

impl CoxModel {
    pub(crate) fn from_parts(
        feature_names: Vec<String>,
        beta: Vec<f64>,
        baseline: BreslowBaseline,
        diagnostics: FitDiagnostics,
    ) -> Self {
        CoxModel { feature_names, beta, baseline, diagnostics }
    }

    /// Coefficient vector in the dataset's feature order.
    pub fn beta(&self) -> &[f64] {
        &self.beta
    }

    /// Number of features the model was trained on.
    pub fn p(&self) -> usize {
        self.beta.len()
    }

    pub fn feature_names(&self) -> &[String] {
        &self.feature_names
    }

    /// The fitted Breslow baseline cumulative hazard.
    pub fn baseline(&self) -> &BreslowBaseline {
        &self.baseline
    }

    pub fn diagnostics(&self) -> &FitDiagnostics {
        &self.diagnostics
    }

    /// All coefficients keyed by original feature index and name.
    pub fn coefficients(&self) -> Vec<Coefficient> {
        self.beta
            .iter()
            .enumerate()
            .map(|(index, &value)| Coefficient {
                index,
                name: self.feature_names[index].clone(),
                value,
            })
            .collect()
    }

    /// Coefficients with `|value| > threshold` (the selected features),
    /// sorted by descending magnitude.
    pub fn nonzero_coefficients(&self, threshold: f64) -> Vec<Coefficient> {
        let mut out: Vec<Coefficient> = self
            .coefficients()
            .into_iter()
            .filter(|c| c.value.abs() > threshold)
            .collect();
        out.sort_by(|a, b| {
            b.value.abs().partial_cmp(&a.value.abs()).unwrap_or(std::cmp::Ordering::Equal)
        });
        out
    }

    fn check_features(&self, x: &Matrix) -> Result<()> {
        if x.cols != self.beta.len() {
            return Err(FastSurvivalError::InvalidData(format!(
                "feature-count mismatch: model has {} coefficients, input has {} columns",
                self.beta.len(),
                x.cols
            )));
        }
        Ok(())
    }

    /// Linear risk scores η = Xβ (higher = higher hazard).
    pub fn predict_risk(&self, x: &Matrix) -> Result<Vec<f64>> {
        self.check_features(x)?;
        Ok(x.matvec(&self.beta))
    }

    /// Individual survival probabilities S(t | x_i) = exp(−H₀(t)·e^{η_i}).
    ///
    /// The baseline step table is consulted once for the whole batch
    /// (one binary search), not once per row.
    pub fn predict_survival(&self, x: &Matrix, t: f64) -> Result<Vec<f64>> {
        if !t.is_finite() {
            return Err(FastSurvivalError::InvalidData(format!(
                "survival horizon must be finite, got {t}"
            )));
        }
        let eta = self.predict_risk(x)?;
        let h = self.baseline.cumulative_hazard(t);
        Ok(eta.iter().map(|&e| (-h * e.exp()).exp()).collect())
    }

    /// Full survival curves: S(h | x_i) for every row at every horizon,
    /// returned as one `Vec<f64>` per row (in `horizons` order).
    ///
    /// η = Xβ is computed once, and H₀ is evaluated at all horizons in
    /// a single merged scan over the baseline step table
    /// ([`BreslowBaseline::cumulative_hazard_many`]) — callers no longer
    /// re-run `predict_risk` per horizon. Horizons may be unsorted;
    /// duplicates are fine.
    pub fn predict_survival_curve(&self, x: &Matrix, horizons: &[f64]) -> Result<Vec<Vec<f64>>> {
        if let Some(bad) = horizons.iter().find(|h| !h.is_finite()) {
            return Err(FastSurvivalError::InvalidData(format!(
                "survival horizon must be finite, got {bad}"
            )));
        }
        let eta = self.predict_risk(x)?;
        // One merged scan over the step table, caller's horizon order.
        let h0 = self.baseline.cumulative_hazard_unsorted(horizons);
        Ok(eta
            .iter()
            .map(|&e| {
                let ez = e.exp();
                h0.iter().map(|&h| (-h * ez).exp()).collect()
            })
            .collect())
    }

    /// Harrell's concordance index of the model's risk scores on `ds`.
    pub fn concordance(&self, ds: &SurvivalDataset) -> Result<f64> {
        let eta = self.predict_risk(&ds.x)?;
        Ok(concordance_index(&ds.time, &ds.event, &eta))
    }

    // ---------------------------------------------------- persistence

    /// Serialize to the versioned JSON model format.
    pub fn to_json(&self) -> String {
        let d = &self.diagnostics;
        let mut out = String::with_capacity(4096);
        out.push_str("{\n  \"format_version\": ");
        out.push_str(&FORMAT_VERSION.to_string());
        out.push_str(",\n  \"feature_names\": ");
        json::write_str_array(&mut out, &self.feature_names);
        out.push_str(",\n  \"beta\": ");
        json::write_f64_array(&mut out, &self.beta);
        out.push_str(",\n  \"baseline\": {\"times\": ");
        json::write_f64_array(&mut out, &self.baseline.times);
        out.push_str(", \"cumhaz\": ");
        json::write_f64_array(&mut out, &self.baseline.cumhaz);
        out.push_str("},\n  \"diagnostics\": {");
        out.push_str("\"optimizer\": ");
        json::write_str(&mut out, &d.optimizer);
        out.push_str(", \"engine\": ");
        json::write_str(&mut out, &d.engine);
        out.push_str(&format!(", \"iterations\": {}", d.iterations));
        out.push_str(&format!(", \"converged\": {}", d.converged));
        out.push_str(&format!(", \"budget_exhausted\": {}", d.budget_exhausted));
        out.push_str(", \"objective_value\": ");
        json::write_f64(&mut out, d.objective_value);
        out.push_str(", \"l1\": ");
        json::write_f64(&mut out, d.l1);
        out.push_str(", \"l2\": ");
        json::write_f64(&mut out, d.l2);
        out.push_str(&format!(", \"n_train\": {}", d.n_train));
        out.push_str(&format!(", \"n_events\": {}", d.n_events));
        out.push_str(", \"wall_secs\": ");
        json::write_f64(&mut out, d.wall_secs);
        out.push_str(", \"trace\": ");
        write_trace_json(&mut out, &d.trace);
        out.push_str(", \"report\": ");
        write_report_field(&mut out, &d.report);
        out.push_str("}\n}\n");
        out
    }

    /// Rebuild a model from [`CoxModel::to_json`] output. The loss trace
    /// (with per-point sweep counts and KKT residuals) and the optional
    /// observability report round-trip; files saved by older builds load
    /// with an empty trace and no report.
    pub fn from_json(text: &str) -> Result<Self> {
        let doc = json::parse(text)?;
        let version = doc.require("format_version")?.as_usize()?;
        if version != FORMAT_VERSION {
            return Err(FastSurvivalError::Persist(format!(
                "unsupported model format_version {version} (this build reads {FORMAT_VERSION})"
            )));
        }
        let feature_names = doc.require("feature_names")?.as_string_vec()?;
        let beta = doc.require("beta")?.as_f64_vec()?;
        if feature_names.len() != beta.len() {
            return Err(FastSurvivalError::Persist(format!(
                "corrupt model: {} feature names vs {} coefficients",
                feature_names.len(),
                beta.len()
            )));
        }
        if beta.iter().any(|b| !b.is_finite()) {
            return Err(FastSurvivalError::Persist(
                "corrupt model: non-finite coefficient".into(),
            ));
        }
        let bl = doc.require("baseline")?;
        let baseline = BreslowBaseline::from_parts(
            bl.require("times")?.as_f64_vec()?,
            bl.require("cumhaz")?.as_f64_vec()?,
        )?;
        let d = doc.require("diagnostics")?;
        let diagnostics = FitDiagnostics {
            optimizer: d.require("optimizer")?.as_str()?.to_string(),
            engine: d.require("engine")?.as_str()?.to_string(),
            iterations: d.require("iterations")?.as_usize()?,
            converged: d.require("converged")?.as_bool()?,
            budget_exhausted: d.require("budget_exhausted")?.as_bool()?,
            objective_value: d.require("objective_value")?.as_f64()?,
            l1: d.require("l1")?.as_f64()?,
            l2: d.require("l2")?.as_f64()?,
            n_train: d.require("n_train")?.as_usize()?,
            n_events: d.require("n_events")?.as_usize()?,
            wall_secs: d.require("wall_secs")?.as_f64()?,
            trace: match d.get("trace") {
                Some(v) => trace_from_json(v)?,
                None => Trace::default(),
            },
            report: report_from_json(d)?,
        };
        Ok(CoxModel { feature_names, beta, baseline, diagnostics })
    }

    /// Save to a JSON file (parent directories are created).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| FastSurvivalError::io(format!("creating {parent:?}"), e))?;
            }
        }
        std::fs::write(path, self.to_json())
            .map_err(|e| FastSurvivalError::io(format!("writing model to {path:?}"), e))
    }

    /// Load a model saved by [`CoxModel::save`].
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| FastSurvivalError::io(format!("reading model from {path:?}"), e))?;
        Self::from_json(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_model() -> CoxModel {
        let baseline = BreslowBaseline::fit(
            &[1.0, 2.0, 3.0, 4.0],
            &[true, true, false, true],
            &[0.2, -0.1, 0.4, 0.0],
        );
        CoxModel::from_parts(
            vec!["age".into(), "x\"quoted\"".into()],
            vec![0.75, -1.25e-3],
            baseline,
            FitDiagnostics {
                optimizer: "cubic-surrogate".into(),
                engine: "native".into(),
                iterations: 17,
                converged: true,
                budget_exhausted: false,
                objective_value: 3.5,
                l1: 0.5,
                l2: 0.1,
                n_train: 4,
                n_events: 3,
                wall_secs: 0.01,
                trace: Trace {
                    points: vec![
                        TracePoint { iter: 0, secs: 0.001, loss: 4.0, sweeps: 1, kkt: None },
                        TracePoint {
                            iter: 1,
                            secs: 0.002,
                            loss: 3.5,
                            sweeps: 2,
                            kkt: Some(1.25e-7),
                        },
                    ],
                    diverged: false,
                    converged: true,
                    budget_exhausted: false,
                },
                report: None,
            },
        )
    }

    #[test]
    fn json_round_trip_is_exact() {
        let m = toy_model();
        let r = CoxModel::from_json(&m.to_json()).unwrap();
        assert_eq!(m.beta, r.beta);
        assert_eq!(m.feature_names, r.feature_names);
        assert_eq!(m.baseline.times, r.baseline.times);
        assert_eq!(m.baseline.cumhaz, r.baseline.cumhaz);
        let (d, e) = (m.diagnostics(), r.diagnostics());
        assert_eq!(d.iterations, e.iterations);
        assert_eq!(d.converged, e.converged);
        assert_eq!(d.optimizer, e.optimizer);
        assert_eq!(d.objective_value, e.objective_value);
        // The loss trace round-trips point for point, including the
        // per-point sweep counts and optional KKT residuals.
        assert_eq!(d.trace.points.len(), e.trace.points.len());
        for (a, b) in d.trace.points.iter().zip(e.trace.points.iter()) {
            assert_eq!(a.iter, b.iter);
            assert_eq!(a.sweeps, b.sweeps);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.kkt, b.kkt);
        }
        assert_eq!(d.trace.converged, e.trace.converged);
        assert!(e.report.is_none());
    }

    #[test]
    fn fit_report_round_trips_on_the_model() {
        let mut m = toy_model();
        m.diagnostics.report = Some(FitReport {
            phases: vec![crate::obs::report::PhaseReport {
                phase: "cd_sweep".into(),
                count: 7,
                total_ns: 9000,
                self_ns: 8000,
            }],
            counters: crate::obs::CounterSnapshot {
                kernel_simd: 42,
                workspace_hits: 3,
                ..Default::default()
            },
        });
        let r = CoxModel::from_json(&m.to_json()).unwrap();
        assert_eq!(r.diagnostics.report, m.diagnostics.report);
    }

    #[test]
    fn coefficients_keyed_by_index_and_name() {
        let m = toy_model();
        let cs = m.coefficients();
        assert_eq!(cs.len(), 2);
        assert_eq!(cs[0].index, 0);
        assert_eq!(cs[0].name, "age");
        assert_eq!(cs[0].value, 0.75);
        let nz = m.nonzero_coefficients(0.01);
        assert_eq!(nz.len(), 1, "tiny coefficient filtered");
        assert_eq!(nz[0].name, "age");
    }

    #[test]
    fn predict_rejects_wrong_width() {
        let m = toy_model();
        let x = Matrix::from_columns(&[vec![1.0, 2.0]]);
        assert!(m.predict_risk(&x).is_err());
        assert!(m.predict_survival(&x, 1.0).is_err());
        assert!(m.predict_survival_curve(&x, &[1.0]).is_err());
    }

    #[test]
    fn survival_curve_matches_per_horizon_predictions() {
        let m = toy_model();
        let x = Matrix::from_columns(&[vec![1.0, 0.2, -0.5], vec![0.0, 1.0, 2.0]]);
        // Unsorted horizons with a duplicate and an off-grid point.
        let horizons = [2.5, 0.5, 4.0, 2.5, 100.0];
        let curves = m.predict_survival_curve(&x, &horizons).unwrap();
        assert_eq!(curves.len(), 3);
        for (j, &t) in horizons.iter().enumerate() {
            let single = m.predict_survival(&x, t).unwrap();
            for i in 0..3 {
                assert_eq!(
                    curves[i][j].to_bits(),
                    single[i].to_bits(),
                    "row {i} horizon {t}"
                );
            }
        }
        // Non-finite horizons are rejected like predict_survival's.
        assert!(m.predict_survival_curve(&x, &[1.0, f64::NAN]).is_err());
        assert!(m.predict_survival(&x, f64::INFINITY).is_err());
        // Empty horizon grid → empty curves, not an error.
        assert_eq!(m.predict_survival_curve(&x, &[]).unwrap()[0].len(), 0);
    }

    #[test]
    fn load_rejects_corrupt_documents() {
        let m = toy_model();
        let good = m.to_json();
        assert!(CoxModel::from_json("{}").is_err());
        assert!(CoxModel::from_json(&good.replace("\"format_version\": 1", "\"format_version\": 99"))
            .is_err());
        // Truncations are syntax errors.
        assert!(CoxModel::from_json(&good[..good.len() / 2]).is_err());
    }
}
