//! Minimal JSON reader/writer for model persistence (the offline build
//! has no `serde`).
//!
//! Writing uses Rust's shortest-round-trip `f64` formatting, so a
//! save → load cycle reproduces coefficients and baseline hazards
//! bit-for-bit; non-finite values serialize as `null` and parse back as
//! NaN. The parser is a strict recursive-descent implementation of the
//! JSON grammar (objects, arrays, strings with escapes, numbers, bools,
//! null) that rejects trailing garbage.

use crate::error::{FastSurvivalError, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

fn err(msg: impl Into<String>) -> FastSurvivalError {
    FastSurvivalError::Persist(msg.into())
}

impl Json {
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Object field lookup that errors with the missing key's name.
    pub fn require(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| err(format!("missing field {key:?}")))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(v) => Ok(*v),
            // Non-finite values are serialized as null.
            Json::Null => Ok(f64::NAN),
            other => Err(err(format!("expected number, found {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= u32::MAX as f64 => {
                Ok(*v as usize)
            }
            other => Err(err(format!("expected non-negative integer, found {other:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(err(format!("expected bool, found {other:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(err(format!("expected string, found {other:?}"))),
        }
    }

    pub fn as_array(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(items) => Ok(items),
            other => Err(err(format!("expected array, found {other:?}"))),
        }
    }

    /// Array of numbers → `Vec<f64>`.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_array()?.iter().map(Json::as_f64).collect()
    }

    /// Array of strings → `Vec<String>`.
    pub fn as_string_vec(&self) -> Result<Vec<String>> {
        self.as_array()?
            .iter()
            .map(|v| v.as_str().map(str::to_string))
            .collect()
    }

    /// Serialize this value back to compact JSON text, appending to
    /// `out`. Field order is preserved (objects keep insertion order),
    /// numbers use the same shortest-round-trip formatting as
    /// [`write_f64`], so `parse` → `write_to` → `parse` is lossless. The
    /// scoring server uses this to build response documents.
    pub fn write_to(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => write_f64(out, *v),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_to(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write_to(out);
                }
                out.push('}');
            }
        }
    }

    /// [`Json::write_to`] into a fresh `String`.
    pub fn to_json_string(&self) -> String {
        let mut out = String::new();
        self.write_to(&mut out);
        out
    }
}

// ---------------------------------------------------------------- writer

/// Append a JSON string literal (with escapes) to `out`.
pub fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Append an f64 with shortest-round-trip formatting (`null` if not
/// finite, so the output stays valid JSON).
pub fn write_f64(out: &mut String, v: f64) {
    if v.is_finite() {
        out.push_str(&format!("{v}"));
        // Bare integers like "3" parse back exactly; no suffix needed.
    } else {
        out.push_str("null");
    }
}

/// Append `[v0,v1,...]` of f64.
pub fn write_f64_array(out: &mut String, vs: &[f64]) {
    out.push('[');
    for (i, v) in vs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        write_f64(out, *v);
    }
    out.push(']');
}

/// Append `["a","b",...]` of strings.
pub fn write_str_array(out: &mut String, vs: &[String]) {
    out.push('[');
    for (i, v) in vs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        write_str(out, v);
    }
    out.push(']');
}

// ---------------------------------------------------------------- parser

/// Maximum container nesting. The parser recurses per level, so without
/// a cap a small all-`[` document could overflow the thread stack —
/// fatal, not catchable — once untrusted bodies arrive over HTTP. 128
/// levels is far beyond any model artifact or scoring request.
const MAX_DEPTH: usize = 128;

/// Parse a complete JSON document (rejects trailing non-whitespace and
/// nesting deeper than [`MAX_DEPTH`]).
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(err(format!("trailing characters at byte {}", p.pos)));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(err(format!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )))
        }
    }

    fn eat_keyword(&mut self, kw: &str) -> bool {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            true
        } else {
            false
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') if self.eat_keyword("true") => Ok(Json::Bool(true)),
            Some(b'f') if self.eat_keyword("false") => Ok(Json::Bool(false)),
            Some(b'n') if self.eat_keyword("null") => Ok(Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(err(format!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            ))),
        }
    }

    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(err(format!(
                "document nests deeper than {MAX_DEPTH} levels at byte {}",
                self.pos
            )));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        self.enter()?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(err(format!("expected ',' or '}}' at byte {}", self.pos))),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        self.enter()?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(err(format!("expected ',' or ']' at byte {}", self.pos))),
            }
        }
    }

    fn hex4(&mut self) -> Result<u16> {
        if self.pos + 4 > self.bytes.len() {
            return Err(err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| err("invalid \\u escape"))?;
        let v = u16::from_str_radix(s, 16).map_err(|_| err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => {
                            out.push('"');
                            self.pos += 1;
                        }
                        Some(b'\\') => {
                            out.push('\\');
                            self.pos += 1;
                        }
                        Some(b'/') => {
                            out.push('/');
                            self.pos += 1;
                        }
                        Some(b'n') => {
                            out.push('\n');
                            self.pos += 1;
                        }
                        Some(b'r') => {
                            out.push('\r');
                            self.pos += 1;
                        }
                        Some(b't') => {
                            out.push('\t');
                            self.pos += 1;
                        }
                        Some(b'b') => {
                            out.push('\u{0008}');
                            self.pos += 1;
                        }
                        Some(b'f') => {
                            out.push('\u{000c}');
                            self.pos += 1;
                        }
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair: require \uXXXX low half.
                                if !self.eat_keyword("\\u") {
                                    return Err(err("unpaired surrogate in \\u escape"));
                                }
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(err("invalid low surrogate in \\u escape"));
                                }
                                let cp = 0x10000
                                    + ((hi as u32 - 0xD800) << 10)
                                    + (lo as u32 - 0xDC00);
                                char::from_u32(cp).ok_or_else(|| err("invalid code point"))?
                            } else {
                                char::from_u32(hi as u32)
                                    .ok_or_else(|| err("invalid code point"))?
                            };
                            out.push(c);
                        }
                        other => {
                            return Err(err(format!(
                                "invalid escape {:?}",
                                other.map(|c| c as char)
                            )))
                        }
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is a &str, so byte
                    // boundaries are valid; find the char at this offset).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| err("invalid utf-8"))?;
                    let c = rest.chars().next().ok_or_else(|| err("unterminated string"))?;
                    if (c as u32) < 0x20 {
                        return Err(err("unescaped control character in string"));
                    }
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| err("invalid number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| err(format!("invalid number {s:?} at byte {start}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_f64_exactly() {
        let vals = [
            0.0,
            -0.0,
            1.5,
            -3.25e-17,
            std::f64::consts::PI,
            1e300,
            f64::MIN_POSITIVE,
            123456789.123456789,
        ];
        let mut out = String::new();
        write_f64_array(&mut out, &vals);
        let parsed = parse(&out).unwrap().as_f64_vec().unwrap();
        for (a, b) in vals.iter().zip(&parsed) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }

    #[test]
    fn non_finite_serializes_as_null_and_parses_as_nan() {
        let mut out = String::new();
        write_f64_array(&mut out, &[f64::NAN, f64::INFINITY, 1.0]);
        assert_eq!(out, "[null,null,1]");
        let v = parse(&out).unwrap().as_f64_vec().unwrap();
        assert!(v[0].is_nan() && v[1].is_nan());
        assert_eq!(v[2], 1.0);
    }

    #[test]
    fn string_escapes_round_trip() {
        let names = vec![
            "plain".to_string(),
            "has \"quotes\" and \\slashes\\".to_string(),
            "tab\there\nnewline".to_string(),
            "unicode: β ≤ λ₂ 💡".to_string(),
            "control: \u{0007}".to_string(),
        ];
        let mut out = String::new();
        write_str_array(&mut out, &names);
        let parsed = parse(&out).unwrap().as_string_vec().unwrap();
        assert_eq!(names, parsed);
    }

    #[test]
    fn parses_nested_object() {
        let doc = r#" { "a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "e": "x" } "#;
        let v = parse(doc).unwrap();
        assert_eq!(v.require("a").unwrap().as_f64_vec().unwrap(), vec![1.0, 2.5, -300.0]);
        assert!(v.require("b").unwrap().require("c").unwrap().as_bool().unwrap());
        assert_eq!(v.require("e").unwrap().as_str().unwrap(), "x");
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn surrogate_pair_escape() {
        let v = parse(r#""💡""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "💡");
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\" 1}",
            "[1] trailing",
            "\"unterminated",
            "nul",
            "{\"a\": 1,}",
            "[01abc]",
        ] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn nesting_depth_is_capped() {
        // Within the cap: parses fine.
        let ok = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&ok).is_ok());
        // A small hostile all-'[' body must be a typed error, not a
        // recursion-driven stack overflow.
        let deep = "[".repeat(100_000);
        assert!(parse(&deep).is_err());
        let mixed = "{\"a\":".repeat(1_000) + "1" + &"}".repeat(1_000);
        assert!(parse(&mixed).is_err());
    }

    #[test]
    fn value_serializer_round_trips() {
        let doc = r#"{"a":[1,2.5,-300],"b":{"c":true,"d":null},"e":"x\"y\"","f":[[],{}]}"#;
        let v = parse(doc).unwrap();
        let text = v.to_json_string();
        assert_eq!(parse(&text).unwrap(), v, "write_to must be parse-invertible");
        // Compact output with preserved field order is byte-stable.
        assert_eq!(text, parse(&text).unwrap().to_json_string());
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(parse("42").unwrap().as_usize().unwrap(), 42);
        assert!(parse("-1").unwrap().as_usize().is_err());
        assert!(parse("1.5").unwrap().as_usize().is_err());
    }
}
