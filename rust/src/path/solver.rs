//! The warm-started λ-path solver: one screened active-set engine for the
//! whole regularization path.
//!
//! Per grid point (λ descending):
//! 1. **Warm start** from the previous point's solution (β, η, and the
//!    exp(η) weights carry over — nothing is recomputed from zeros).
//! 2. **Sequential strong rule** (Tibshirani et al. 2012): a coordinate
//!    enters the candidate set only if it is already active or its
//!    gradient at the previous solution exceeds `2λ_k − λ_{k−1}` (in
//!    ℓ1-penalty units). This is a heuristic discard, so —
//! 3. **Active-set CD with KKT-residual stopping**: the quadratic or
//!    cubic surrogate sweeps the candidates, and each sweep stops when
//!    the largest per-coordinate KKT residual (measured from the same
//!    derivative pass the step uses — see
//!    [`SurrogateKind::step_residual`]) falls below
//!    `stop_rel · λ_max`. Residual-based stopping is what makes warm
//!    starts pay: a point that starts essentially converged exits after
//!    one cheap sweep, while a loss-change rule would need several
//!    sweeps just to observe flatness — and the residual bounds the loss
//!    suboptimality *quadratically*, which is how warm and cold solves
//!    land on the same losses to ~1e-9.
//!    Then a **full KKT check** over all p coordinates catches any
//!    wrongly-discarded feature; violators are added and CD resumes.
//!    A point is accepted only when no coordinate violates its KKT
//!    condition, so screening can never change the solution — only the
//!    work done to reach it.
//! 4. One [`Workspace`] and one Lipschitz table serve the entire path:
//!    the version-tagged risk-set cache persists across grid points.

use super::lambda::{lambda_max_l1, log_grid};
use crate::cox::derivatives::{beta_gradient_ws, Workspace};
use crate::cox::lipschitz::all_lipschitz;
use crate::cox::loss::loss;
use crate::cox::{CoxProblem, CoxState};
use crate::error::{FastSurvivalError, Result};
use crate::optim::cd::SurrogateKind;
use crate::optim::Objective;
use crate::util::compute::{default_backend, KernelBackend};

/// Configuration of the λ-path solve.
#[derive(Clone, Debug)]
pub struct PathSolver {
    /// Number of grid points.
    pub n_lambdas: usize,
    /// λ_min / λ_max ratio of the log-spaced grid.
    pub min_ratio: f64,
    /// ElasticNet mixing: penalty = λ·(l1_ratio·‖β‖₁ + (1−l1_ratio)·‖β‖₂²).
    /// Must be in (0, 1] — a pure-ridge path has no sparsity to exploit.
    pub l1_ratio: f64,
    /// Surrogate supplying the coordinate step.
    pub surrogate: SurrogateKind,
    /// CD sweeps per KKT round.
    pub max_sweeps: usize,
    /// Inner stopping tolerance: a sweep's largest per-coordinate KKT
    /// residual must fall below `stop_rel · λ_max` (ℓ1-gradient units).
    /// The loss suboptimality this leaves is O(residual²) — far tighter
    /// than the residual itself.
    pub stop_rel: f64,
    /// Absolute floor on the screening-repair slack for the
    /// zero-coordinate KKT condition |∇_l| ≤ λ1.
    pub kkt_tol: f64,
    /// Apply the sequential strong rule (false = every coordinate is a
    /// candidate at every point; the solution is identical by the KKT
    /// guarantee, only slower — the cold reference in benchmarks).
    pub screen: bool,
    /// Warm-start each point from the previous solution (false = restart
    /// from zeros per point; the cold reference in benchmarks).
    pub warm_start: bool,
    /// Safety cap on add-violators-and-resume rounds per point.
    pub max_kkt_rounds: usize,
    /// Derivative kernel backend for every coordinate step on the path
    /// (resolved by the caller; see [`crate::util::compute::Compute`]).
    pub backend: KernelBackend,
}

impl Default for PathSolver {
    fn default() -> Self {
        PathSolver {
            n_lambdas: 50,
            min_ratio: 0.01,
            l1_ratio: 1.0,
            surrogate: SurrogateKind::Cubic,
            max_sweeps: 1000,
            stop_rel: 1e-6,
            kkt_tol: 1e-7,
            screen: true,
            warm_start: true,
            max_kkt_rounds: 50,
            backend: default_backend(),
        }
    }
}

/// One accepted grid point.
#[derive(Clone, Debug)]
pub struct PathPoint {
    /// Grid value λ (penalty = λ·(l1_ratio·‖β‖₁ + (1−l1_ratio)·‖β‖₂²)).
    pub lambda: f64,
    /// Effective ℓ1 weight λ·l1_ratio.
    pub l1: f64,
    /// Effective ℓ2 weight λ·(1−l1_ratio).
    pub l2: f64,
    /// Dense coefficient vector.
    pub beta: Vec<f64>,
    /// Indices of nonzero coefficients, ascending.
    pub support: Vec<usize>,
    /// Unpenalized CPH training loss at `beta`.
    pub train_loss: f64,
    /// Penalized objective at `beta`.
    pub objective_value: f64,
    /// CD sweeps spent on this point (all KKT rounds).
    pub sweeps: usize,
    /// KKT rounds (1 = the strong rule discarded no active feature).
    pub kkt_rounds: usize,
    /// Candidate-set size after screening, before KKT repair.
    pub screened: usize,
}

/// A whole solved λ-path.
#[derive(Clone, Debug)]
pub struct LambdaPath {
    pub points: Vec<PathPoint>,
}

impl LambdaPath {
    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The grid, in solve order (descending λ).
    pub fn lambdas(&self) -> Vec<f64> {
        self.points.iter().map(|p| p.lambda).collect()
    }

    /// Total CD sweeps across the path (the work metric benchmarks track).
    pub fn total_sweeps(&self) -> usize {
        self.points.iter().map(|p| p.sweeps).sum()
    }
}

impl PathSolver {
    fn validate(&self) -> Result<()> {
        if self.n_lambdas == 0 {
            return Err(FastSurvivalError::InvalidConfig(
                "path needs at least one λ grid point".into(),
            ));
        }
        if !(self.min_ratio > 0.0 && self.min_ratio <= 1.0) {
            return Err(FastSurvivalError::InvalidConfig(format!(
                "min_ratio must be in (0, 1], got {}",
                self.min_ratio
            )));
        }
        if !(self.l1_ratio > 0.0 && self.l1_ratio <= 1.0) {
            return Err(FastSurvivalError::InvalidConfig(format!(
                "l1_ratio must be in (0, 1] (a pure-ridge path has no sparsity), got {}",
                self.l1_ratio
            )));
        }
        if self.max_sweeps == 0 || self.max_kkt_rounds == 0 {
            return Err(FastSurvivalError::InvalidConfig(
                "max_sweeps and max_kkt_rounds must be at least 1".into(),
            ));
        }
        if !self.stop_rel.is_finite()
            || self.stop_rel < 0.0
            || !self.kkt_tol.is_finite()
            || self.kkt_tol < 0.0
        {
            return Err(FastSurvivalError::InvalidConfig(format!(
                "tolerances must be finite and non-negative (stop_rel={}, kkt_tol={})",
                self.stop_rel, self.kkt_tol
            )));
        }
        Ok(())
    }

    /// λ_max for this problem under the configured `l1_ratio`.
    pub fn lambda_max(&self, problem: &CoxProblem) -> Result<f64> {
        self.validate()?;
        let lmax_l1 = lambda_max_l1(problem);
        if lmax_l1 <= 0.0 {
            return Err(FastSurvivalError::InvalidData(
                "λ_max is zero: the gradient at β = 0 vanishes (no usable signal)".into(),
            ));
        }
        Ok(lmax_l1 / self.l1_ratio)
    }

    /// The data-derived log-spaced grid (descending).
    pub fn lambda_grid(&self, problem: &CoxProblem) -> Result<Vec<f64>> {
        Ok(log_grid(self.lambda_max(problem)?, self.min_ratio, self.n_lambdas))
    }

    /// Solve the whole path on the data-derived grid.
    pub fn run(&self, problem: &CoxProblem) -> Result<LambdaPath> {
        let grid = self.lambda_grid(problem)?;
        self.run_grid(problem, &grid)
    }

    /// Solve the path on an explicit λ grid (descending order expected —
    /// cross-validation fits every fold on the full-data grid so scores
    /// align across folds).
    pub fn run_grid(&self, problem: &CoxProblem, lambdas: &[f64]) -> Result<LambdaPath> {
        self.validate()?;
        if lambdas.is_empty() {
            return Err(FastSurvivalError::InvalidConfig("empty λ grid".into()));
        }
        let p = problem.p();
        let lip = all_lipschitz(problem);
        let mut ws = Workspace::default();
        let mut state = CoxState::zeros(problem);
        // Gradient at the current warm state; at zeros to begin with. Its
        // max-abs is λ_max in ℓ1 units — the strong rule's "previous λ"
        // for the first grid point, and the scale of the residual-based
        // inner stopping rule.
        let mut grad = beta_gradient_ws(problem, &state, &mut ws);
        let grad0 = grad.clone();
        let lmax_l1 = grad.iter().fold(0.0_f64, |m, &v| m.max(v.abs()));
        let mut prev_l1 = lmax_l1;
        let stop_eps = self.stop_rel * lmax_l1;
        // The screening repair uses the same slack the inner loop stops
        // at (plus the absolute floor), so a coordinate the sweeps would
        // leave alone is never flagged as a violation.
        let kkt_slack = stop_eps.max(self.kkt_tol);

        let mut points = Vec::with_capacity(lambdas.len());
        for &lambda in lambdas {
            let obj = Objective {
                l1: lambda * self.l1_ratio,
                l2: lambda * (1.0 - self.l1_ratio),
            };
            if !self.warm_start {
                state = CoxState::zeros(problem);
                grad.clone_from(&grad0);
                prev_l1 = lmax_l1;
            }

            // Candidate set: the strong rule plus everything already active.
            let (mut active, mut coords) = if self.screen {
                let _span = crate::obs::SpanTimer::start(crate::obs::Phase::PathScreen);
                let thr = (2.0 * obj.l1 - prev_l1).max(0.0);
                let mut active = vec![false; p];
                let mut coords: Vec<usize> = Vec::new();
                for l in 0..p {
                    if state.beta[l] != 0.0 || grad[l].abs() > thr {
                        active[l] = true;
                        coords.push(l);
                    }
                }
                (active, coords)
            } else {
                (vec![true; p], (0..p).collect::<Vec<usize>>())
            };
            let screened = coords.len();
            crate::obs::counters::screened_skips((p - screened) as u64);

            let mut sweeps = 0;
            let mut kkt_rounds = 0;
            loop {
                kkt_rounds += 1;
                // Inner CD: sweep the candidates until the largest
                // pre-step KKT residual seen in a sweep drops below
                // stop_eps (or a sweep moves nothing at all — no further
                // progress is possible past the step-snap floor).
                for _ in 0..self.max_sweeps {
                    if coords.is_empty() {
                        // Nothing screened in (the λ_max endpoint).
                        break;
                    }
                    let mut max_res = 0.0_f64;
                    let mut moved = false;
                    for &l in &coords {
                        let (delta, res) = self.surrogate.step_residual_b(
                            problem, &mut state, &mut ws, l, lip[l], obj, stop_eps,
                            self.backend,
                        );
                        if res > max_res {
                            max_res = res;
                        }
                        if delta != 0.0 {
                            moved = true;
                        }
                    }
                    sweeps += 1;
                    if max_res <= stop_eps || !moved {
                        break;
                    }
                }
                // Full KKT sweep: any zero coordinate outside the candidate
                // set with |∇_l| > λ1 was wrongly discarded — repair and
                // resume. (Candidates with β = 0 are already being swept,
                // so only non-candidates can violate.)
                let kkt_span =
                    crate::obs::SpanTimer::start(crate::obs::Phase::PathKktRepair);
                grad = beta_gradient_ws(problem, &state, &mut ws);
                let mut violations = 0;
                for l in 0..p {
                    if !active[l] && grad[l].abs() > obj.l1 + kkt_slack {
                        active[l] = true;
                        coords.push(l);
                        violations += 1;
                    }
                }
                drop(kkt_span);
                if violations == 0 || kkt_rounds >= self.max_kkt_rounds {
                    break;
                }
                crate::obs::counters::kkt_repair_rounds(1);
            }
            let objective_value = obj.value(problem, &state);

            let support: Vec<usize> =
                (0..p).filter(|&l| state.beta[l] != 0.0).collect();
            points.push(PathPoint {
                lambda,
                l1: obj.l1,
                l2: obj.l2,
                beta: state.beta.clone(),
                support,
                train_loss: loss(problem, &state),
                objective_value,
                sweeps,
                kkt_rounds,
                screened,
            });
            prev_l1 = obj.l1;
        }
        Ok(LambdaPath { points })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};

    fn problem(n: usize, p: usize, seed: u64) -> CoxProblem {
        let ds = generate(&SyntheticConfig { n, p, rho: 0.4, k: 3, s: 0.1, seed });
        CoxProblem::new(&ds)
    }

    #[test]
    fn empty_model_at_lambda_max_and_growth_below() {
        let pr = problem(200, 12, 81);
        let path = PathSolver { n_lambdas: 20, ..Default::default() }.run(&pr).unwrap();
        assert_eq!(path.len(), 20);
        assert_eq!(path.points[0].support.len(), 0, "λ_max point must be empty");
        let last = path.points.last().unwrap();
        assert!(!last.support.is_empty(), "λ_min point must be non-trivial");
        // Training loss is non-increasing as λ shrinks (weaker penalty,
        // warm-started monotone CD).
        for w in path.points.windows(2) {
            assert!(
                w[1].train_loss <= w[0].train_loss + 1e-7,
                "loss must not increase along the path: {} -> {}",
                w[0].train_loss,
                w[1].train_loss
            );
        }
    }

    #[test]
    fn screened_path_matches_unscreened() {
        let pr = problem(150, 15, 82);
        let tight = PathSolver {
            n_lambdas: 12,
            stop_rel: 1e-8,
            ..Default::default()
        };
        let screened = tight.run(&pr).unwrap();
        let unscreened =
            PathSolver { screen: false, ..tight.clone() }.run(&pr).unwrap();
        let support = |beta: &[f64]| -> Vec<usize> {
            beta.iter()
                .enumerate()
                .filter(|(_, b)| b.abs() > 1e-10)
                .map(|(i, _)| i)
                .collect()
        };
        for (a, b) in screened.points.iter().zip(unscreened.points.iter()) {
            // Thresholded comparison: the two solves sweep coordinates in
            // different orders, so a boundary coefficient may end as an
            // exact 0.0 in one and ~1e-14 in the other.
            assert_eq!(
                support(&a.beta),
                support(&b.beta),
                "screening changed the support at λ={}",
                a.lambda
            );
            let gap = (a.train_loss - b.train_loss).abs() / (1.0 + b.train_loss.abs());
            assert!(
                gap < 1e-8,
                "λ={}: {} vs {} (gap {gap:.3e})",
                a.lambda,
                a.train_loss,
                b.train_loss
            );
        }
        // And screening actually screened: the candidate set was smaller
        // than p somewhere on the path.
        assert!(
            screened.points.iter().any(|pt| pt.screened < pr.p()),
            "strong rule never discarded anything"
        );
    }

    #[test]
    fn kkt_conditions_hold_at_every_accepted_point() {
        let pr = problem(120, 10, 83);
        let path = PathSolver { n_lambdas: 8, stop_rel: 1e-8, ..Default::default() }
            .run(&pr)
            .unwrap();
        for pt in &path.points {
            let st = CoxState::from_beta(&pr, &pt.beta);
            let g = crate::cox::derivatives::beta_gradient(&pr, &st);
            for l in 0..pr.p() {
                let pg = g[l] + 2.0 * pt.l2 * pt.beta[l];
                if pt.beta[l] != 0.0 {
                    assert!(
                        (pg + pt.l1 * pt.beta[l].signum()).abs() < 1e-4,
                        "active KKT at λ={} l={l}: {pg}",
                        pt.lambda
                    );
                } else {
                    assert!(
                        pg.abs() <= pt.l1 + 1e-4,
                        "zero KKT at λ={} l={l}: |{pg}| > {}",
                        pt.lambda,
                        pt.l1
                    );
                }
            }
        }
    }

    #[test]
    fn invalid_configs_are_rejected() {
        let pr = problem(60, 4, 84);
        assert!(PathSolver { n_lambdas: 0, ..Default::default() }.run(&pr).is_err());
        assert!(PathSolver { min_ratio: 0.0, ..Default::default() }.run(&pr).is_err());
        assert!(PathSolver { l1_ratio: 0.0, ..Default::default() }.run(&pr).is_err());
        assert!(PathSolver { stop_rel: f64::NAN, ..Default::default() }.run(&pr).is_err());
    }

    #[test]
    fn elastic_net_path_runs() {
        let pr = problem(100, 8, 85);
        let path = PathSolver { n_lambdas: 6, l1_ratio: 0.5, ..Default::default() }
            .run(&pr)
            .unwrap();
        assert_eq!(path.len(), 6);
        assert!(path.points.iter().all(|pt| (pt.l1 - pt.l2).abs() < 1e-12));
    }
}
