//! The k-path: cardinality-constrained solutions for k = 1..K through
//! the same warm-started discipline as the λ-path.
//!
//! Beam search is path-native (each level extends the previous level's
//! states), so its whole run *is* the k-path. ABESS is chained: the k
//! solve warm-starts from the k−1 solution's state, with one Lipschitz
//! table and one risk-set workspace shared across the whole path.

use crate::cox::derivatives::Workspace;
use crate::cox::lipschitz::all_lipschitz;
use crate::cox::{CoxProblem, CoxState};
use crate::select::{Abess, BeamSearch, SparseSolution};

/// One support size on the k-path.
#[derive(Clone, Debug)]
pub struct CardinalityPoint {
    /// Support size (number of nonzero coefficients).
    pub k: usize,
    /// Indices of nonzero coefficients, ascending.
    pub support: Vec<usize>,
    /// Dense coefficient vector.
    pub beta: Vec<f64>,
    /// Unpenalized CPH training loss at `beta`.
    pub train_loss: f64,
}

impl From<SparseSolution> for CardinalityPoint {
    fn from(s: SparseSolution) -> Self {
        CardinalityPoint { k: s.k, support: s.support, beta: s.beta, train_loss: s.train_loss }
    }
}

/// A whole solved k-path (ascending k).
#[derive(Clone, Debug)]
pub struct CardinalityPath {
    pub points: Vec<CardinalityPoint>,
}

impl CardinalityPath {
    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The point at exactly size `k`, if the solver reached it.
    pub fn point_for_k(&self, k: usize) -> Option<&CardinalityPoint> {
        self.points.iter().find(|p| p.k == k)
    }

    fn from_solutions(mut sols: Vec<SparseSolution>) -> Self {
        sols.sort_by_key(|s| s.k);
        CardinalityPath { points: sols.into_iter().map(CardinalityPoint::from).collect() }
    }

    /// k-path via beam search (the paper's ℓ0 method): one expansion run
    /// yields every size 1..=max_k.
    pub fn run_beam(problem: &CoxProblem, max_k: usize, beam: &BeamSearch) -> Self {
        Self::from_solutions(beam.run(problem, max_k))
    }

    /// k-path via ABESS splicing, warm-started k−1 → k with a shared
    /// Lipschitz table and workspace.
    pub fn run_abess(problem: &CoxProblem, max_k: usize, abess: &Abess) -> Self {
        let max_k = max_k.min(problem.p());
        let lip = all_lipschitz(problem);
        let mut ws = Workspace::default();
        let mut warm: Option<CoxState> = None;
        let mut sols = Vec::with_capacity(max_k);
        for k in 1..=max_k {
            let (sol, state) = abess.run_k_from(problem, k, warm.as_ref(), &lip, &mut ws);
            sols.push(sol);
            warm = Some(state);
        }
        Self::from_solutions(sols)
    }
}

/// Which k-path engine to run — the typed registry behind the CLI's
/// `--method` flag and cardinality cross-validation.
#[derive(Clone, Debug)]
pub enum CardinalitySolver {
    Beam(BeamSearch),
    Abess(Abess),
}

impl CardinalitySolver {
    pub fn name(&self) -> &'static str {
        match self {
            CardinalitySolver::Beam(_) => "fastsurvival-beam",
            CardinalitySolver::Abess(_) => "abess",
        }
    }

    /// Solve the k-path for sizes 1..=max_k.
    pub fn run(&self, problem: &CoxProblem, max_k: usize) -> CardinalityPath {
        match self {
            CardinalitySolver::Beam(b) => CardinalityPath::run_beam(problem, max_k, b),
            CardinalitySolver::Abess(a) => CardinalityPath::run_abess(problem, max_k, a),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};

    fn problem(seed: u64) -> CoxProblem {
        let ds = generate(&SyntheticConfig { n: 220, p: 15, rho: 0.3, k: 3, s: 0.1, seed });
        CoxProblem::new(&ds)
    }

    #[test]
    fn beam_path_covers_sizes_and_improves() {
        let pr = problem(91);
        let path = CardinalityPath::run_beam(
            &pr,
            5,
            &BeamSearch { width: 3, screen: 8, ..Default::default() },
        );
        assert!(path.len() >= 4, "beam path too short: {}", path.len());
        for w in path.points.windows(2) {
            assert!(w[1].k > w[0].k);
            assert!(w[1].train_loss <= w[0].train_loss + 1e-9);
        }
        assert!(path.point_for_k(3).is_some());
    }

    #[test]
    fn abess_path_is_warm_chained_and_monotone() {
        let pr = problem(92);
        let path = CardinalityPath::run_abess(&pr, 5, &Abess::default());
        assert_eq!(path.len(), 5);
        for (i, pt) in path.points.iter().enumerate() {
            assert_eq!(pt.k, i + 1);
            assert_eq!(pt.support.len(), pt.k);
        }
        for w in path.points.windows(2) {
            assert!(
                w[1].train_loss <= w[0].train_loss + 1e-6,
                "k-path loss increased: {} -> {}",
                w[0].train_loss,
                w[1].train_loss
            );
        }
    }
}
