//! λ-grid construction for the regularization path.
//!
//! `λ_max` is the smallest penalty at which β = 0 is optimal: the KKT
//! condition at zero is `|∇_l ℓ(0)| ≤ λ1` for every l, so
//! `λ_max = max_l |∇_l ℓ(0)| / l1_ratio`. The grid is log-spaced from
//! λ_max down to `min_ratio · λ_max` — the glmnet/Coxnet convention the
//! paper's baselines use.

use crate::cox::derivatives::{beta_gradient_ws, Workspace};
use crate::cox::{CoxProblem, CoxState};

/// `max_l |∇_l ℓ(0)|` — λ_max in ℓ1-penalty units (divide by the
/// elastic-net `l1_ratio` for the λ of a mixed penalty).
pub fn lambda_max_l1(problem: &CoxProblem) -> f64 {
    let state = CoxState::zeros(problem);
    let g = beta_gradient_ws(problem, &state, &mut Workspace::default());
    g.iter().fold(0.0_f64, |m, &v| m.max(v.abs()))
}

/// Log-spaced grid of `n` values from `lmax` down to `lmax · min_ratio`
/// (descending; `n = 1` yields just `lmax`).
pub fn log_grid(lmax: f64, min_ratio: f64, n: usize) -> Vec<f64> {
    assert!(n >= 1 && lmax > 0.0 && min_ratio > 0.0 && min_ratio <= 1.0);
    let denom = (n - 1).max(1) as f64;
    (0..n).map(|i| lmax * min_ratio.powf(i as f64 / denom)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};

    #[test]
    fn grid_is_descending_and_hits_both_ends() {
        let g = log_grid(10.0, 0.01, 5);
        assert_eq!(g.len(), 5);
        assert!((g[0] - 10.0).abs() < 1e-12);
        assert!((g[4] - 0.1).abs() < 1e-12);
        for w in g.windows(2) {
            assert!(w[1] < w[0], "grid must descend: {w:?}");
        }
        assert_eq!(log_grid(3.0, 0.5, 1), vec![3.0]);
    }

    #[test]
    fn lambda_max_zeroes_the_model() {
        // At λ = λ_max every coordinate satisfies the zero-KKT condition,
        // so |∇_l ℓ(0)| ≤ λ_max for all l with equality at the argmax.
        let ds = generate(&SyntheticConfig { n: 120, p: 8, rho: 0.3, k: 2, s: 0.1, seed: 5 });
        let pr = CoxProblem::new(&ds);
        let lmax = lambda_max_l1(&pr);
        assert!(lmax > 0.0);
        let g = crate::cox::derivatives::beta_gradient(&pr, &CoxState::zeros(&pr));
        for v in &g {
            assert!(v.abs() <= lmax + 1e-12);
        }
        assert!(g.iter().any(|v| (v.abs() - lmax).abs() < 1e-12));
    }
}
