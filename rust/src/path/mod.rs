//! Whole-path solvers: λ-paths and k-paths over one screened,
//! warm-started active-set engine.
//!
//! The paper's headline application is families of sparse CPH models —
//! every support size k and every penalty strength λ — not a single fit.
//! This module makes paths first-class:
//!
//! - [`lambda`] derives the log-spaced λ grid from the data's λ_max;
//! - [`solver::PathSolver`] walks the grid with warm starts, sequential
//!   strong-rule screening, and a full KKT check per accepted point, all
//!   through one shared [`crate::cox::derivatives::Workspace`] and one
//!   Lipschitz table;
//! - [`cardinality::CardinalityPath`] produces k = 1..K solutions with
//!   each size warm-started from the previous one (beam search or ABESS).
//!
//! The public `CoxFit::l1_path` / `CoxFit::cardinality_path` builders and
//! the CLI `path` subcommand sit on top; path-based cross-validation
//! lives in [`crate::coordinator::cv`].

pub mod cardinality;
pub mod lambda;
pub mod solver;

pub use cardinality::{CardinalityPath, CardinalityPoint, CardinalitySolver};
pub use lambda::{lambda_max_l1, log_grid};
pub use solver::{LambdaPath, PathPoint, PathSolver};
