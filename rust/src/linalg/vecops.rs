//! Small vector helpers shared by optimizers and metrics.

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    if alpha == 0.0 {
        return;
    }
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Infinity norm.
#[inline]
pub fn norm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |m, &v| m.max(v.abs()))
}

/// Number of entries with |x| > tol.
pub fn support_size(x: &[f64], tol: f64) -> usize {
    x.iter().filter(|v| v.abs() > tol).count()
}

/// Indices of entries with |x| > tol.
pub fn support(x: &[f64], tol: f64) -> Vec<usize> {
    x.iter()
        .enumerate()
        .filter(|(_, v)| v.abs() > tol)
        .map(|(i, _)| i)
        .collect()
}

/// Soft-threshold operator S(x, t) = sign(x) * max(|x| - t, 0).
#[inline]
pub fn soft_threshold(x: f64, t: f64) -> f64 {
    if x > t {
        x - t
    } else if x < -t {
        x + t
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_axpy_norms() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        let mut y = b;
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [6.0, 9.0, 12.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        assert_eq!(norm_inf(&[-7.0, 2.0]), 7.0);
    }

    #[test]
    fn support_helpers() {
        let x = [0.0, 1e-12, -0.5, 2.0];
        assert_eq!(support_size(&x, 1e-9), 2);
        assert_eq!(support(&x, 1e-9), vec![2, 3]);
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
    }
}
