//! Minimal dense linear algebra (no external crates offline).
//!
//! Provides the column-major [`Matrix`] used throughout, Cholesky
//! factorization for SPD Newton systems, and small vector helpers.

pub mod cholesky;
pub mod matrix;
pub mod vecops;

pub use cholesky::Cholesky;
pub use matrix::Matrix;
