//! Dense column-major matrix.
//!
//! Column-major because the Cox coordinate-descent hot path walks single
//! feature columns (`x_l` over all samples) — those must be contiguous.

/// Dense column-major `rows x cols` matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    /// Column-major storage: element (r, c) at `data[c * rows + r]`.
    pub data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    /// Build from row-major nested vectors (test convenience).
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut m = Matrix::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            for (j, &v) in row.iter().enumerate() {
                m.set(i, j, v);
            }
        }
        m
    }

    /// Build from column vectors.
    pub fn from_columns(cols: &[Vec<f64>]) -> Self {
        let c = cols.len();
        let r = if c == 0 { 0 } else { cols[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for col in cols {
            assert_eq!(col.len(), r, "ragged columns");
            data.extend_from_slice(col);
        }
        Matrix { rows: r, cols: c, data }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[c * self.rows + r]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[c * self.rows + r] = v;
    }

    /// Contiguous view of column `c`.
    #[inline]
    pub fn col(&self, c: usize) -> &[f64] {
        &self.data[c * self.rows..(c + 1) * self.rows]
    }

    /// Mutable view of column `c`.
    #[inline]
    pub fn col_mut(&mut self, c: usize) -> &mut [f64] {
        &mut self.data[c * self.rows..(c + 1) * self.rows]
    }

    /// Copy of row `r`.
    pub fn row(&self, r: usize) -> Vec<f64> {
        (0..self.cols).map(|c| self.get(r, c)).collect()
    }

    /// Matrix-vector product `A x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for c in 0..self.cols {
            let xc = x[c];
            if xc == 0.0 {
                continue; // sparse β fast path: skip zero coefficients
            }
            let col = self.col(c);
            for (yi, &a) in y.iter_mut().zip(col) {
                *yi += a * xc;
            }
        }
        y
    }

    /// Transposed product `A^T x`.
    pub fn tr_matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        (0..self.cols)
            .map(|c| {
                let col = self.col(c);
                col.iter().zip(x).map(|(&a, &b)| a * b).sum()
            })
            .collect()
    }

    /// Dense product `A * B`.
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows);
        let mut out = Matrix::zeros(self.rows, b.cols);
        for j in 0..b.cols {
            let bj = b.col(j);
            let oj = out.col_mut(j);
            for (k, &bkj) in bj.iter().enumerate() {
                if bkj == 0.0 {
                    continue;
                }
                let ak = self.col(k);
                for (o, &a) in oj.iter_mut().zip(ak) {
                    *o += a * bkj;
                }
            }
        }
        out
    }

    /// Subset of columns (for restricted-support fits).
    pub fn select_columns(&self, idx: &[usize]) -> Matrix {
        let cols: Vec<Vec<f64>> = idx.iter().map(|&c| self.col(c).to_vec()).collect();
        Matrix::from_columns(&cols)
    }

    /// Subset of rows (for CV folds / bootstrap).
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut m = Matrix::zeros(idx.len(), self.cols);
        for c in 0..self.cols {
            let src = self.col(c);
            let dst = m.col_mut(c);
            for (k, &r) in idx.iter().enumerate() {
                dst[k] = src[r];
            }
        }
        m
    }

    /// Transpose.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for c in 0..self.cols {
            for r in 0..self.rows {
                t.set(c, r, self.get(r, c));
            }
        }
        t
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Round every cell through f32 in place (mixed-precision storage
    /// semantics): after this, the matrix holds exactly the values an
    /// f32-cell store would decode, while every kernel keeps accumulating
    /// in f64. Idempotent — quantizing twice is a no-op.
    pub fn quantize_f32(&mut self) {
        for v in self.data.iter_mut() {
            *v = *v as f32 as f64;
        }
    }

    /// Standardize columns in place to mean 0 / std 1; returns (means, stds).
    /// Constant columns keep std=1 so they become all-zero rather than NaN.
    pub fn standardize_columns(&mut self) -> (Vec<f64>, Vec<f64>) {
        let n = self.rows as f64;
        let mut means = Vec::with_capacity(self.cols);
        let mut stds = Vec::with_capacity(self.cols);
        for c in 0..self.cols {
            let col = self.col_mut(c);
            let mean = col.iter().sum::<f64>() / n;
            let var = col.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
            let std = if var > 1e-24 { var.sqrt() } else { 1.0 };
            for x in col.iter_mut() {
                *x = (*x - mean) / std;
            }
            means.push(mean);
            stds.push(std);
        }
        (means, stds)
    }
}

/// Dense column-major `rows x cols` matrix of f32 **cells** — the
/// storage half of the mixed-precision path. Holding features as f32
/// halves the memory footprint and bandwidth of a column scan; all
/// arithmetic happens after widening each cell to f64, so accumulation
/// precision is unchanged (fits agree with f64 storage to ≤1e-6 per
/// coefficient, the storage quantization error).
#[derive(Clone, Debug, PartialEq)]
pub struct F32Matrix {
    pub rows: usize,
    pub cols: usize,
    /// Column-major storage: element (r, c) at `data[c * rows + r]`.
    pub data: Vec<f32>,
}

impl F32Matrix {
    /// Quantize an f64 matrix down to f32 cells.
    pub fn from_matrix(m: &Matrix) -> Self {
        F32Matrix {
            rows: m.rows,
            cols: m.cols,
            data: m.data.iter().map(|&v| v as f32).collect(),
        }
    }

    /// Contiguous view of column `c`.
    #[inline]
    pub fn col(&self, c: usize) -> &[f32] {
        &self.data[c * self.rows..(c + 1) * self.rows]
    }

    /// Widen column `c` into `out` (cleared first) for the f64 kernels.
    pub fn widen_col_into(&self, c: usize, out: &mut Vec<f64>) {
        out.clear();
        out.extend(self.col(c).iter().map(|&v| v as f64));
    }

    /// Widen the whole matrix back to f64. The result is exactly what
    /// [`Matrix::quantize_f32`] produces from the original matrix.
    pub fn to_matrix(&self) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| v as f64).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn col_major_layout() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.col(0), &[1.0, 3.0]);
        assert_eq!(m.col(1), &[2.0, 4.0]);
        assert_eq!(m.get(0, 1), 2.0);
    }

    #[test]
    fn matvec_and_transpose() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        assert_eq!(m.matvec(&[1.0, 1.0]), vec![3.0, 7.0, 11.0]);
        assert_eq!(m.tr_matvec(&[1.0, 1.0, 1.0]), vec![9.0, 12.0]);
        assert_eq!(m.transpose().get(1, 2), 6.0);
    }

    #[test]
    fn matmul_matches_manual() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.row(0), vec![19.0, 22.0]);
        assert_eq!(c.row(1), vec![43.0, 50.0]);
    }

    #[test]
    fn select_rows_cols() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let s = m.select_columns(&[2, 0]);
        assert_eq!(s.col(0), &[3.0, 6.0]);
        let r = m.select_rows(&[1]);
        assert_eq!(r.row(0), vec![4.0, 5.0, 6.0]);
    }

    #[test]
    fn standardize_handles_constant_column() {
        let mut m = Matrix::from_columns(&[vec![2.0, 2.0, 2.0], vec![0.0, 1.0, 2.0]]);
        let (means, stds) = m.standardize_columns();
        assert_eq!(means[0], 2.0);
        assert_eq!(stds[0], 1.0);
        assert!(m.col(0).iter().all(|&x| x == 0.0));
        assert!(m.col(1).iter().sum::<f64>().abs() < 1e-12);
    }

    #[test]
    fn eye_matvec_identity() {
        let m = Matrix::eye(4);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(m.matvec(&x), x);
    }

    #[test]
    fn quantize_f32_matches_f32_round_trip_and_is_idempotent() {
        let mut m = Matrix::from_columns(&[
            vec![0.1, -2.7e10, 3.333_333_333_333, 0.0],
            vec![1.0 / 3.0, f64::MIN_POSITIVE, 7.25, -0.1],
        ]);
        let quantized_ref: Vec<f64> = m.data.iter().map(|&v| v as f32 as f64).collect();
        m.quantize_f32();
        assert_eq!(m.data, quantized_ref);
        let once = m.clone();
        m.quantize_f32();
        assert_eq!(m, once, "quantization must be idempotent");
        // Values exactly representable in f32 pass through untouched.
        assert_eq!(m.get(2, 1), 7.25);
        assert_eq!(m.get(3, 0), 0.0);
    }

    #[test]
    fn f32_matrix_round_trips_through_quantization() {
        let mut m = Matrix::from_columns(&[vec![0.1, 0.2, -0.3], vec![1.5, -2.5, 3.5]]);
        let f = F32Matrix::from_matrix(&m);
        assert_eq!(f.rows, 3);
        assert_eq!(f.cols, 2);
        assert_eq!(f.col(1), &[1.5f32, -2.5, 3.5]);
        let mut widened = Vec::new();
        f.widen_col_into(0, &mut widened);
        m.quantize_f32();
        assert_eq!(widened.as_slice(), m.col(0));
        assert_eq!(f.to_matrix(), m);
    }
}
