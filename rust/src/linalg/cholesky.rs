//! Cholesky factorization and SPD solves for Newton systems.
//!
//! The exact-Newton baseline solves `(H + λI) Δ = -g` with `H` the full
//! β-space Hessian (Sec. 2 of the paper). `H` is positive semidefinite, so
//! Cholesky with a diagonal-jitter retry is the right factorization.

use super::matrix::Matrix;

/// Lower-triangular Cholesky factor `L` with `A = L L^T`.
#[derive(Debug, Clone)]
pub struct Cholesky {
    l: Matrix,
}

/// Error when the matrix is not (numerically) positive definite.
#[derive(Debug, Clone, PartialEq)]
pub struct NotPositiveDefinite {
    pub pivot: usize,
    pub value: f64,
}

impl std::fmt::Display for NotPositiveDefinite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "matrix not positive definite at pivot {} (value {:.3e})",
            self.pivot, self.value
        )
    }
}

impl std::error::Error for NotPositiveDefinite {}

impl Cholesky {
    /// Factor a symmetric positive-definite matrix.
    pub fn factor(a: &Matrix) -> Result<Self, NotPositiveDefinite> {
        assert_eq!(a.rows, a.cols, "Cholesky requires a square matrix");
        let n = a.rows;
        let mut l = Matrix::zeros(n, n);
        for j in 0..n {
            let mut d = a.get(j, j);
            for k in 0..j {
                let ljk = l.get(j, k);
                d -= ljk * ljk;
            }
            if d <= 0.0 || !d.is_finite() {
                return Err(NotPositiveDefinite { pivot: j, value: d });
            }
            let djs = d.sqrt();
            l.set(j, j, djs);
            for i in (j + 1)..n {
                let mut s = a.get(i, j);
                for k in 0..j {
                    s -= l.get(i, k) * l.get(j, k);
                }
                l.set(i, j, s / djs);
            }
        }
        Ok(Cholesky { l })
    }

    /// Factor with escalating diagonal jitter (for PSD Hessians at β far
    /// from the optimum where curvature vanishes — the paper's flaw #1).
    pub fn factor_with_jitter(a: &Matrix, base_jitter: f64) -> (Self, f64) {
        if let Ok(c) = Cholesky::factor(a) {
            return (c, 0.0);
        }
        let mut jitter = base_jitter.max(1e-12);
        loop {
            let mut aj = a.clone();
            for i in 0..a.rows {
                aj.set(i, i, aj.get(i, i) + jitter);
            }
            if let Ok(c) = Cholesky::factor(&aj) {
                return (c, jitter);
            }
            jitter *= 10.0;
            assert!(jitter < 1e12, "could not regularize matrix to SPD");
        }
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        // Forward: L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l.get(i, k) * y[k];
            }
            y[i] = s / self.l.get(i, i);
        }
        // Backward: L^T x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= self.l.get(k, i) * x[k];
            }
            x[i] = s / self.l.get(i, i);
        }
        x
    }

    /// log-determinant of A (useful for diagnostics).
    pub fn logdet(&self) -> f64 {
        (0..self.l.rows).map(|i| self.l.get(i, i).ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut b = Matrix::zeros(n, n);
        for c in 0..n {
            for r in 0..n {
                b.set(r, c, rng.normal());
            }
        }
        // A = B B^T + n * I is SPD.
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a.set(i, i, a.get(i, i) + n as f64);
        }
        a
    }

    #[test]
    fn solve_recovers_solution() {
        for seed in 0..5 {
            let n = 8;
            let a = random_spd(n, seed);
            let mut rng = Rng::new(100 + seed);
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&x_true);
            let c = Cholesky::factor(&a).unwrap();
            let x = c.solve(&b);
            for (xi, ti) in x.iter().zip(&x_true) {
                assert!((xi - ti).abs() < 1e-8, "{xi} vs {ti}");
            }
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eigvals 3, -1
        assert!(Cholesky::factor(&a).is_err());
    }

    #[test]
    fn jitter_recovers_psd() {
        // Rank-deficient PSD matrix.
        let a = Matrix::from_rows(&[vec![1.0, 1.0], vec![1.0, 1.0]]);
        let (c, jitter) = Cholesky::factor_with_jitter(&a, 1e-8);
        assert!(jitter > 0.0);
        let x = c.solve(&[1.0, 1.0]);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn logdet_identity_zero() {
        let c = Cholesky::factor(&Matrix::eye(5)).unwrap();
        assert!(c.logdet().abs() < 1e-12);
    }
}
