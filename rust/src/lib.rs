//! FastSurvival: fast training of Cox proportional hazards (CPH) models.
//!
//! Reproduction of "FastSurvival: Hidden Computational Blessings in Training
//! Cox Proportional Hazards Models" (Liu, Zhang, Rudin; NeurIPS 2024).
//!
//! Three-layer architecture:
//! - Layer 1 (build time): Pallas kernels computing risk-set cumulative
//!   moments, lowered to HLO via `python/compile/aot.py`.
//! - Layer 2 (build time): JAX compute graphs (loss, per-coordinate and
//!   all-coordinate derivatives), also lowered to HLO.
//! - Layer 3 (this crate): the optimization coordinator. The public
//!   entrypoint is [`api`] — a `CoxFit` builder that selects a problem,
//!   an engine (native kernels or the AOT-XLA artifacts), and an
//!   optimizer through one path, and returns a fitted `CoxModel` with
//!   prediction, evaluation, and JSON persistence — or a whole `CoxPath`
//!   (λ-path / k-path) through the warm-started screened active-set
//!   engine in [`path`]. Beneath them live the quadratic/cubic surrogate
//!   coordinate descent and Newton-family baselines ([`optim`]),
//!   beam-search variable selection ([`select`]), metrics, datasets,
//!   path-based cross-validation, and the experiment harness.
//!   Prediction-time workloads go through [`serve`]: a hot-swappable
//!   model registry, a batched scoring engine with micro-batching, and
//!   a zero-dependency multi-threaded HTTP scoring server. Datasets too
//!   big for RAM go through [`store`]: a sorted columnar on-disk format
//!   (`.fsds`) with streaming ingestion and a chunked two-phase trainer
//!   (sampled-block warmup + exact out-of-core surrogate CD) that
//!   matches the in-memory fit bit for bit. Data that keeps arriving
//!   goes through [`live`]: crash-safe segment appends over a base
//!   store, incremental warm refits carrying a KKT parity certificate,
//!   and a watch → validate → publish loop into the serving registry.
//!   Every engine reports where its time and sweeps go through [`obs`]:
//!   span timing over a fixed phase taxonomy, engine counters, per-fit
//!   reports in model diagnostics, JSONL traces (`--trace-out` /
//!   `profile`), and training gauges surfaced by `/metrics`.

pub mod api;
pub mod baselines;
pub mod coordinator;
pub mod cox;
pub mod data;
pub mod error;
pub mod linalg;
pub mod live;
pub mod metrics;
pub mod obs;
pub mod optim;
pub mod path;
pub mod runtime;
pub mod select;
pub mod serve;
pub mod store;
pub mod util;

pub use api::{CoxFit, CoxModel, CoxPath, EngineKind, OptimizerKind};
pub use error::{FastSurvivalError, Result};
