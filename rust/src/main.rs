//! FastSurvival CLI — the Layer-3 coordinator entrypoint.
//!
//! Subcommands:
//!   fit          train a CPH model on a dataset (CoxFit builder API)
//!   path         whole solution paths: λ grid or cardinality k = 1..K
//!   select       cardinality-constrained variable selection
//!   experiment   regenerate a paper table/figure (see DESIGN.md)
//!   datasets     list datasets (Table 1 view)
//!   convert      stream a CSV or the synthetic generator into a .fsds store
//!   bigfit       tracked out-of-core workload + gates → BENCH_bigfit.json
//!   bench        fixed-seed hot-path benchmarks → BENCH_optim.json
//!   profile      phase table from a training trace, or per-endpoint stage
//!                table from a serve access log / /debug/trace dump
//!   serve        HTTP scoring server over a model-artifact directory
//!   score        offline batch scoring: CSV in → CSV out, streamed
//!   serve-smoke  off/on serving burst + obs gates → BENCH_serve.json
//!   append       append rows to a .fsds store as a committed live segment
//!   inspect      dump + verify a .fsds store (header, meta, segments)
//!   watch        online loop: detect appends, warm-refit, gated publish
//!   live-smoke   append → warm refit → gated publish + gates → BENCH_live.json
//!
//! Examples:
//!   fastsurvival fit --dataset flchain --method cubic --l2 1
//!   fastsurvival fit --dataset synthetic --engine xla
//!   fastsurvival fit --csv data/mydata.csv --l2 0.5
//!   fastsurvival fit --store data/big.fsds --method quadratic --l2 1
//!   fastsurvival fit --dataset synthetic --save artifacts/serving/churn@1.json
//!   fastsurvival convert --input data/mydata.csv --out data/mydata.fsds
//!   fastsurvival convert --synthetic --n 1000000 --p 100 --out data/big.fsds
//!   fastsurvival convert --synthetic --n 1000000 --out data/big.fsds --shards 4
//!   fastsurvival bigfit --quick --out BENCH_bigfit.json
//!   fastsurvival inspect --store data/big.fsds.shards.json
//!   fastsurvival path --dataset synthetic --lambdas 50 --save results/path.json
//!   fastsurvival path --kind cardinality --k 10 --cv 5 --criterion cindex
//!   fastsurvival select --dataset synthetic --method beam --k 15
//!   fastsurvival experiment --id fig1 --scale 0.25
//!   fastsurvival bench --quick --check ci/bench_baseline.json
//!   fastsurvival serve --models artifacts/serving --addr 127.0.0.1:7878
//!   fastsurvival score --model churn@1.json --input data.csv --output scores.csv
//!   fastsurvival serve-smoke --out BENCH_serve.json
//!   fastsurvival append --store data/big.fsds --input data/new_rows.csv
//!   fastsurvival inspect --store data/big.fsds
//!   fastsurvival watch --store data/big.fsds --models artifacts/serving --name churn
//!   fastsurvival live-smoke --out BENCH_live.json
//!
//! Every failure path (bad names, invalid data, missing artifacts,
//! unknown subcommands) surfaces as a typed `FastSurvivalError`, not a
//! panic or a silent fallthrough.

use fastsurvival::api::{CoxFit, CoxModel, CoxPath, EngineKind, OptimizerKind, PathKind};
use fastsurvival::coordinator::cv::{cv_cardinality_path, cv_l1_path, SelectionCriterion};
use fastsurvival::coordinator::experiments::{self, ExperimentConfig};
use fastsurvival::cox::CoxProblem;
use fastsurvival::optim::SurrogateKind;
use fastsurvival::path::{CardinalitySolver, PathSolver};
use fastsurvival::data::binarize::{binarize, BinarizeConfig};
use fastsurvival::data::synthetic::{generate, SyntheticConfig};
use fastsurvival::data::{datasets, SurvivalDataset};
use fastsurvival::error::{FastSurvivalError, Result};
use fastsurvival::live::{self, Watcher};
use fastsurvival::metrics::concordance_index;
use fastsurvival::optim::Objective;
use fastsurvival::select::{Abess, AdaptiveLasso, BeamSearch, CoxnetPath, VariableSelector};
use fastsurvival::serve::registry::ModelRegistry;
use fastsurvival::serve::scorer::{score_csv, BatchConfig, CompiledModel};
use fastsurvival::serve::{serve, smoke, HttpClient, ServeConfig};
use fastsurvival::store::{
    convert_csv_sharded, convert_csv_with, convert_synthetic_sharded, convert_synthetic_with,
    SyntheticRows,
};
use fastsurvival::util::args::Args;
use fastsurvival::util::compute::{Compute, Precision};
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Load the dataset a subcommand asked for: `--csv <file>` streams a
/// real CSV (missing/garbled files are typed errors, not panics),
/// otherwise `--dataset` picks the synthetic generator or a Table-1
/// stand-in.
fn load_dataset(args: &Args) -> Result<SurvivalDataset> {
    if let Some(csv) = args.get("csv") {
        let path = Path::new(csv);
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "csv".to_string());
        return fastsurvival::data::csv::load_survival_csv(path, &name);
    }
    let name = args.str_or("dataset", "synthetic");
    let seed = args.get_or::<u64>("seed", 0);
    if name == "synthetic" {
        let cfg = SyntheticConfig {
            n: args.get_or("n", 600),
            p: args.get_or("p", 100),
            rho: args.get_or("rho", 0.9),
            k: args.get_or("true-k", 10),
            s: 0.1,
            seed,
        };
        return Ok(generate(&cfg));
    }
    let scale = args.get_or::<f64>("scale", 0.25);
    let mut spec = datasets::spec(&name);
    spec.n = ((spec.n as f64 * scale) as usize).max(200);
    let raw = datasets::generate_stand_in(&spec, seed);
    Ok(if args.flag("raw") {
        raw
    } else {
        binarize(
            &raw,
            &BinarizeConfig {
                max_quantiles: args.get_or("quantiles", 25),
                ..Default::default()
            },
        )
    })
}

/// Build the shared compute request from `--backend`, `--threads`,
/// `--precision`, and `--block-rows` (see [`Compute::from_args`]).
fn compute_from_args(args: &Args) -> Result<Compute> {
    Compute::from_args(args)
}

/// Run a subcommand under an optional `--trace-out <file>` tracing
/// session: arm the span sink, wrap the whole run in a root `fit` span
/// (so the serial self-time table reconciles against the wall clock),
/// and write the aggregate JSONL trace when the command finishes. With
/// no `--trace-out`, tracing stays disabled and the only overhead per
/// span site is one relaxed atomic load.
fn with_trace<F: FnOnce(&Args) -> Result<()>>(
    cmd: &'static str,
    args: &Args,
    f: F,
) -> Result<()> {
    let Some(path) = args.get("trace-out").map(|s| s.to_string()) else {
        return f(args);
    };
    fastsurvival::obs::set_enabled(true);
    fastsurvival::obs::reset();
    let t0 = Instant::now();
    let res = {
        let _root = fastsurvival::obs::SpanTimer::start(fastsurvival::obs::Phase::Fit);
        f(args)
    };
    let wall_secs = t0.elapsed().as_secs_f64();
    let threads = compute_from_args(args)
        .and_then(|c| c.resolve())
        .map(|rc| rc.threads)
        .unwrap_or(1);
    let written = fastsurvival::obs::write_trace_jsonl(&path, cmd, wall_secs, threads);
    fastsurvival::obs::set_enabled(false);
    res?;
    written?;
    println!("trace: wrote {path} (inspect with: fastsurvival profile --trace {path})");
    Ok(())
}

/// The `fit` subcommand: one `CoxFit` builder call regardless of
/// optimizer or engine; `--store <file.fsds>` routes to the out-of-core
/// chunked fit instead of loading a dataset.
fn cmd_fit(args: &Args) -> Result<()> {
    if let Some(store) = args.get("store") {
        let store = store.to_string();
        return cmd_fit_store(args, &store);
    }
    let ds = load_dataset(args)?;
    let optimizer = OptimizerKind::from_name(&args.str_or("method", "cubic"))?;
    let engine = EngineKind::from_name(&args.str_or("engine", "native"))?;
    println!(
        "fit: dataset={} n={} p={} events={} optimizer={} engine={}",
        ds.name,
        ds.n(),
        ds.p(),
        ds.n_events(),
        optimizer.name(),
        engine.name()
    );

    let model = CoxFit::new()
        .l1(args.get_or("l1", 0.0))
        .l2(args.get_or("l2", 0.0))
        .optimizer(optimizer)
        .engine(engine)
        .artifact_dir(args.str_or("artifacts", "artifacts"))
        .max_iters(args.get_or("iters", 200))
        .tol(args.get_or("tol", 1e-9))
        .budget_secs(args.get_or("budget-secs", 0.0))
        .compute(compute_from_args(args)?)
        .fit(&ds)?;

    let d = model.diagnostics();
    println!(
        "{}: final objective {:.6} after {} iterations in {:.1} ms \
         (converged={}, budget_exhausted={}, monotone={})",
        d.optimizer,
        d.objective_value,
        d.iterations,
        d.wall_secs * 1e3,
        d.converged,
        d.budget_exhausted,
        d.trace.monotone(1e-8)
    );
    let ci = model.concordance(&ds)?;
    let nonzero = model.nonzero_coefficients(1e-10);
    println!(
        "nonzero coefficients: {} / {}; train CIndex {:.4}",
        nonzero.len(),
        model.p(),
        ci
    );
    if args.flag("print-beta") {
        for c in &nonzero {
            println!("  {} = {:+.6}", c.name, c.value);
        }
    }
    if let Some(path) = args.get("save") {
        let path = Path::new(path);
        model.save(path)?;
        // Round-trip sanity: the loaded model must predict identically.
        // Cheap relative to the fit, and it catches a corrupt write at
        // the moment it happens rather than at serving time.
        let loaded = CoxModel::load(path)?;
        let a = model.predict_risk(&ds.x)?;
        let b = loaded.predict_risk(&ds.x)?;
        assert_eq!(a, b, "model round-trip changed predictions");
        println!("saved model to {} ({} features)", path.display(), loaded.p());
    }
    Ok(())
}

/// Out-of-core fit: `fit --store big.fsds`.
fn cmd_fit_store(args: &Args, store: &str) -> Result<()> {
    let optimizer = OptimizerKind::from_name(&args.str_or("method", "quadratic"))?;
    // Plumb --engine through so a non-native request is the builder's
    // typed Unsupported error rather than a silently native run.
    let engine = EngineKind::from_name(&args.str_or("engine", "native"))?;
    println!(
        "fit: store={store} optimizer={} engine={} (out-of-core)",
        optimizer.name(),
        engine.name()
    );
    let model = CoxFit::new()
        .l1(args.get_or("l1", 0.0))
        .l2(args.get_or("l2", 0.0))
        .optimizer(optimizer)
        .engine(engine)
        .max_iters(args.get_or("iters", 200))
        .tol(args.get_or("tol", 1e-9))
        .stop_kkt(args.get_or("stop-kkt", 0.0))
        .budget_secs(args.get_or("budget-secs", 0.0))
        .compute(compute_from_args(args)?)
        .fit_store(Path::new(store))?;
    let d = model.diagnostics();
    println!(
        "{}: final objective {:.6} after {} sweeps over n={} in {:.1} ms \
         (converged={}, budget_exhausted={})",
        d.optimizer,
        d.objective_value,
        d.iterations,
        d.n_train,
        d.wall_secs * 1e3,
        d.converged,
        d.budget_exhausted,
    );
    if let Some(peak) = fastsurvival::util::mem::peak_rss_bytes() {
        println!("peak RSS {:.1} MB", peak as f64 / 1e6);
    }
    let nonzero = model.nonzero_coefficients(1e-10);
    println!("nonzero coefficients: {} / {}", nonzero.len(), model.p());
    if args.flag("print-beta") {
        for c in &nonzero {
            println!("  {} = {:+.6}", c.name, c.value);
        }
    }
    if let Some(path) = args.get("save") {
        let path = Path::new(path);
        model.save(path)?;
        let loaded = CoxModel::load(path)?;
        println!("saved model to {} ({} features)", path.display(), loaded.p());
    }
    Ok(())
}

/// The `convert` subcommand: stream rows into a `.fsds` columnar store —
/// `--input <csv>` or `--synthetic`, never materializing the matrix.
fn cmd_convert(args: &Args) -> Result<()> {
    let out = args.get("out").ok_or_else(|| {
        FastSurvivalError::InvalidConfig("convert requires --out <file.fsds>".into())
    })?;
    let out_path = Path::new(out);
    let chunk_rows = args.get_or("chunk-rows", 0usize); // 0 = format default
    // --precision f32 writes a v2 store with f32 feature cells (half the
    // feature payload); readers widen to f64 and accumulate in f64.
    let precision = match args.get("precision") {
        Some(p) => Precision::from_name(p)?,
        None => Precision::F64,
    };
    // --shards N writes a time-partitioned shard set under a versioned
    // manifest instead of one monolithic store (see README, "Sharded
    // training"); `bigfit --shards` and `fit_sharded` consume it.
    let shards = args.get_or("shards", 0usize);
    let t0 = Instant::now();
    if shards > 0 {
        return cmd_convert_sharded(args, out_path, chunk_rows, precision, shards, &t0);
    }
    let summary = if args.flag("synthetic") {
        let cfg = SyntheticConfig {
            n: args.get_or("n", 100_000),
            p: args.get_or("p", 100),
            rho: args.get_or("rho", 0.2),
            k: args.get_or("true-k", 10),
            s: 0.1,
            seed: args.get_or("seed", 0),
        };
        println!("convert: streaming synthetic n={} p={} -> {out}", cfg.n, cfg.p);
        convert_synthetic_with(&cfg, out_path, chunk_rows, precision)?
    } else if let Some(input) = args.get("input") {
        let input_path = Path::new(input);
        let name = args.str_or(
            "name",
            &input_path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "csv".to_string()),
        );
        println!("convert: streaming {input} -> {out}");
        convert_csv_with(input_path, out_path, chunk_rows, &name, precision)?
    } else {
        return Err(FastSurvivalError::InvalidConfig(
            "convert requires --input <data.csv> or --synthetic".into(),
        ));
    };
    println!(
        "convert: wrote {} — n={} p={} events={} ({} chunks of <={} rows, {:.1} MB) \
         in {:.1}s",
        out,
        summary.n,
        summary.p,
        summary.n_events,
        summary.n_chunks,
        summary.chunk_rows,
        summary.bytes as f64 / 1e6,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// `convert --shards N`: the sharded variant of [`cmd_convert`].
fn cmd_convert_sharded(
    args: &Args,
    out_path: &Path,
    chunk_rows: usize,
    precision: Precision,
    shards: usize,
    t0: &Instant,
) -> Result<()> {
    let summary = if args.flag("synthetic") {
        let cfg = SyntheticConfig {
            n: args.get_or("n", 100_000),
            p: args.get_or("p", 100),
            rho: args.get_or("rho", 0.2),
            k: args.get_or("true-k", 10),
            s: 0.1,
            seed: args.get_or("seed", 0),
        };
        println!(
            "convert: streaming synthetic n={} p={} -> {} ({} shard(s))",
            cfg.n,
            cfg.p,
            out_path.display(),
            shards
        );
        convert_synthetic_sharded(&cfg, out_path, chunk_rows, precision, shards)?
    } else if let Some(input) = args.get("input") {
        let input_path = Path::new(input);
        let name = args.str_or(
            "name",
            &input_path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "csv".to_string()),
        );
        println!(
            "convert: streaming {input} -> {} ({} shard(s))",
            out_path.display(),
            shards
        );
        convert_csv_sharded(input_path, out_path, chunk_rows, &name, precision, shards)?
    } else {
        return Err(FastSurvivalError::InvalidConfig(
            "convert requires --input <data.csv> or --synthetic".into(),
        ));
    };
    println!(
        "convert: wrote {} — n={} p={} events={} across {} shard(s) \
         (generation {}, chunks of <={} rows, {:.1} MB) in {:.1}s",
        summary.manifest_path.display(),
        summary.n,
        summary.p,
        summary.n_events,
        summary.n_shards,
        summary.generation,
        summary.chunk_rows,
        summary.bytes as f64 / 1e6,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// The `path` subcommand: whole solution families through the
/// warm-started screened path engine, with optional path-based CV.
fn cmd_path(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let kind = args.str_or("kind", "l1");
    let optimizer = OptimizerKind::from_name(&args.str_or("method", "cubic"))?;
    let compute = compute_from_args(args)?;
    let builder = CoxFit::new()
        .optimizer(optimizer)
        .n_lambdas(args.get_or("lambdas", 50))
        .lambda_min_ratio(args.get_or("min-ratio", 0.01))
        .l1_ratio(args.get_or("l1-ratio", 1.0))
        .max_iters(args.get_or("iters", 1000))
        .tol(args.get_or("tol", 1e-9))
        .compute(compute);
    let max_k = args.get_or("k", 10);
    println!(
        "path: dataset={} n={} p={} events={} kind={kind} optimizer={}",
        ds.name,
        ds.n(),
        ds.p(),
        ds.n_events(),
        optimizer.name()
    );

    // One selector serves both the printed path and the CV below, so the
    // two can never disagree on the estimator.
    let card_solver = match args.str_or("selector", "beam").as_str() {
        "beam" => CardinalitySolver::Beam(BeamSearch {
            width: args.get_or("width", 10),
            screen: args.get_or("screen", 20),
            ..Default::default()
        }),
        "abess" => CardinalitySolver::Abess(Abess::default()),
        other => {
            return Err(FastSurvivalError::Unknown {
                kind: "cardinality selector",
                name: other.to_string(),
                expected: "beam|abess",
            })
        }
    };
    let path: CoxPath = match kind.as_str() {
        "l1" => builder.l1_path(&ds)?,
        "cardinality" | "card" => builder.cardinality_path_with(&ds, max_k, &card_solver)?,
        other => {
            return Err(FastSurvivalError::Unknown {
                kind: "path kind",
                name: other.to_string(),
                expected: "l1|cardinality",
            })
        }
    };

    println!(
        "{} path: {} points in {:.1} ms",
        path.kind().name(),
        path.len(),
        path.wall_secs() * 1e3
    );
    for (i, pt) in path.points().iter().enumerate() {
        match pt.lambda {
            Some(l) => println!(
                "  [{i:>3}] lambda={l:<12.6} k={:<4} loss={:<12.4} sweeps={}",
                pt.k, pt.train_loss, pt.iterations
            ),
            None => println!("  [{i:>3}] k={:<4} loss={:<12.4}", pt.k, pt.train_loss),
        }
    }

    if args.flag("cv") {
        let folds = args.get_or("cv", 5);
        let criterion = SelectionCriterion::from_name(&args.str_or("criterion", "deviance"))?;
        let cvres = match path.kind() {
            PathKind::L1 => {
                // Mirror the printed path's configuration, including the
                // surrogate (--method): the CV winner must belong to the
                // same estimator the user just saw.
                let surrogate = match optimizer {
                    OptimizerKind::Quadratic => SurrogateKind::Quadratic,
                    _ => SurrogateKind::Cubic,
                };
                let solver = PathSolver {
                    n_lambdas: args.get_or("lambdas", 50),
                    min_ratio: args.get_or("min-ratio", 0.01),
                    l1_ratio: args.get_or("l1-ratio", 1.0),
                    surrogate,
                    max_sweeps: args.get_or("iters", 1000),
                    stop_rel: args.get_or("stop-rel", 1e-6),
                    backend: compute.resolve()?.backend,
                    ..Default::default()
                };
                cv_l1_path(&ds, &solver, folds, args.get_or("seed", 0), criterion)?
            }
            PathKind::Cardinality => cv_cardinality_path(
                &ds,
                &card_solver,
                max_k,
                folds,
                args.get_or("seed", 0),
                criterion,
            )?,
        };
        let best = cvres.best();
        println!(
            "cv ({} folds, criterion={}): best grid value {:.6} — mean deviance {:.4} ± {:.4}, \
             mean cindex {:.4}, mean support {:.1}",
            cvres.folds,
            cvres.criterion.name(),
            best.grid_value,
            best.mean_test_deviance,
            best.std_test_deviance,
            best.mean_test_cindex,
            best.mean_support
        );
    }

    if let Some(out) = args.get("save") {
        let out = Path::new(out);
        path.save(out)?;
        // Round-trip sanity, mirroring `fit --save`.
        let loaded = CoxPath::load(out)?;
        assert_eq!(loaded.len(), path.len(), "path round-trip changed length");
        println!("saved path to {} ({} points)", out.display(), loaded.len());
    }
    Ok(())
}

fn cmd_select(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let pr = CoxProblem::try_new(&ds)?;
    let k = args.get_or("k", 10);
    let method = args.str_or("method", "beam");
    let selector: Box<dyn VariableSelector> = match method.as_str() {
        "beam" => Box::new(BeamSearch {
            width: args.get_or("width", 10),
            screen: args.get_or("screen", 20),
            ..Default::default()
        }),
        "abess" => Box::new(Abess::default()),
        "coxnet" => Box::new(CoxnetPath::default()),
        "alasso" => Box::new(AdaptiveLasso::default()),
        other => {
            return Err(FastSurvivalError::Unknown {
                kind: "selector",
                name: other.to_string(),
                expected: "beam|abess|coxnet|alasso",
            })
        }
    };
    println!(
        "select: dataset={} n={} p={} method={} k={k}",
        ds.name,
        ds.n(),
        ds.p(),
        selector.name()
    );
    let ks: Vec<usize> = (1..=k).collect();
    let sols = selector.select(&pr, &ks);
    for sol in &sols {
        let eta = ds.x.matvec(&sol.beta);
        let ci = concordance_index(&ds.time, &ds.event, &eta);
        let f1 = ds
            .true_beta
            .as_ref()
            .map(|tb| fastsurvival::metrics::support_f1(tb, &sol.beta, 1e-10).f1);
        println!(
            "  k={:<3} loss={:<12.4} cindex={:.4}{}",
            sol.k,
            sol.train_loss,
            ci,
            f1.map(|v| format!(" f1={v:.3}")).unwrap_or_default()
        );
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig {
        scale: args.get_or("scale", 0.25),
        quantiles: args.get_or("quantiles", 25),
        folds: args.get_or("folds", 5),
        ks: args.list_or("ks", &(1..=10).collect::<Vec<usize>>()),
        optim_iters: args.get_or("optim-iters", 40),
        seed: args.get_or("seed", 0),
        out_dir: args.str_or("out", "results").into(),
    };
    let id = args.str_or("id", "table1");
    experiments::run(&id, &cfg)
}

fn cmd_datasets(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig {
        scale: args.get_or("scale", 0.25),
        quantiles: args.get_or("quantiles", 25),
        ..Default::default()
    };
    experiments::run("table1", &cfg)
}

/// The `serve` subcommand: load a model-artifact directory and run the
/// HTTP scoring server until `--max-secs` elapses (0 = forever).
fn cmd_serve(args: &Args) -> Result<()> {
    let dir = args.str_or("models", "artifacts/serving");
    let registry = Arc::new(ModelRegistry::open(Path::new(&dir))?);
    let state = registry.snapshot();
    println!("serve: loaded {} artifact(s) from {dir}", state.n_artifacts());
    for m in state.list() {
        println!("  {} ({} features, {} nonzero)", m.spec(), m.p(), m.support_len());
    }
    if state.n_artifacts() == 0 {
        println!("  (empty — drop <name>@<version>.json artifacts in and POST /v1/reload)");
    }
    let access_log = args.get("access-log").map(|s| s.to_string());
    let slow_ms = args.get_or("slow-ms", 0u64);
    // Any request-obs sink being asked for turns the recording layer on
    // (it can also be armed independently via --trace-out tracing).
    if access_log.is_some() || slow_ms > 0 || args.flag("request-obs") {
        fastsurvival::obs::set_enabled(true);
    }
    let cfg = ServeConfig {
        addr: args.str_or("addr", "127.0.0.1:7878"),
        workers: args.get_or("workers", ServeConfig::default_workers()),
        max_body_bytes: args.get_or("max-body-kb", 8192usize).saturating_mul(1024),
        batch: BatchConfig {
            max_batch_rows: args.get_or("batch-rows", 4096),
            max_wait_us: args.get_or("batch-wait-us", 150),
        },
        access_log: access_log.clone(),
        slow_ms,
        recorder_capacity: args.get_or("recorder-capacity", 512usize),
    };
    let handle = serve(registry, &cfg)?;
    println!("serve: listening on http://{}", handle.local_addr());
    println!(
        "serve: POST /v1/score · GET /v1/models · POST /v1/reload · GET /healthz · \
         GET /metrics · GET /debug/trace"
    );
    if let Some(path) = &access_log {
        println!("serve: access log → {path} (inspect with: fastsurvival profile --trace {path})");
    }
    let max_secs = args.get_or("max-secs", 0.0_f64);
    if max_secs > 0.0 {
        std::thread::sleep(Duration::from_secs_f64(max_secs));
        println!("serve: --max-secs elapsed, draining in-flight requests");
        handle.shutdown();
        Ok(())
    } else {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
}

/// The `score` subcommand: stream a CSV through a saved model in
/// bounded chunks (`n ≫ RAM` inputs work), writing `risk[,surv@h…]`
/// per row to `--output` (or stdout).
fn cmd_score(args: &Args) -> Result<()> {
    let model_path = args.get("model").ok_or_else(|| {
        FastSurvivalError::InvalidConfig("score requires --model <model.json>".into())
    })?;
    let input_path = args.get("input").ok_or_else(|| {
        FastSurvivalError::InvalidConfig("score requires --input <data.csv>".into())
    })?;
    let model = CoxModel::load(Path::new(model_path))?;
    let compiled = CompiledModel::compile(&model, "cli", 1);
    let horizons = args.list_or::<f64>("horizons", &[]);
    let chunk = args.get_or("chunk", 4096usize);
    let file = std::fs::File::open(input_path)
        .map_err(|e| FastSurvivalError::io(format!("opening {input_path}"), e))?;
    let mut reader = std::io::BufReader::new(file);
    let summary = match args.get("output") {
        Some(output_path) => {
            let out = std::fs::File::create(output_path)
                .map_err(|e| FastSurvivalError::io(format!("creating {output_path}"), e))?;
            let mut writer = std::io::BufWriter::new(out);
            score_csv(&compiled, &mut reader, &mut writer, &horizons, chunk)?
        }
        None => {
            let stdout = std::io::stdout();
            let mut writer = stdout.lock();
            score_csv(&compiled, &mut reader, &mut writer, &horizons, chunk)?
        }
    };
    // Summary on stderr so piped stdout stays pure CSV.
    eprintln!(
        "score: {} rows in {} chunk(s) of ≤{chunk} ({} features, {} nonzero, {} horizons)",
        summary.rows,
        summary.chunks,
        compiled.p(),
        compiled.support_len(),
        horizons.len()
    );
    Ok(())
}

/// The `append` subcommand: stream rows (CSV or synthetic) into a
/// committed segment next to an existing `.fsds` store. `--compact`
/// folds all committed segments back into one base afterwards.
fn cmd_append(args: &Args) -> Result<()> {
    let store = args.get("store").ok_or_else(|| {
        FastSurvivalError::InvalidConfig("append requires --store <file.fsds>".into())
    })?;
    let store = Path::new(store);
    let chunk_rows = args.get_or("chunk-rows", 0usize); // 0 = base chunk size
    let summary = if args.flag("synthetic") {
        let cfg = SyntheticConfig {
            n: args.get_or("n", 1000),
            p: args.get_or("p", 100),
            rho: args.get_or("rho", 0.2),
            k: args.get_or("true-k", 10),
            s: 0.1,
            seed: args.get_or("seed", 0),
        };
        println!("append: streaming synthetic n={} -> {}", cfg.n, store.display());
        let mut rows = SyntheticRows::new(&cfg);
        live::append_rows(store, &mut rows, chunk_rows)?
    } else if let Some(input) = args.get("input") {
        println!("append: streaming {input} -> {}", store.display());
        let mut reader = fastsurvival::data::csv::open_survival_csv(Path::new(input))?;
        live::append_rows(store, &mut reader, chunk_rows)?
    } else {
        return Err(FastSurvivalError::InvalidConfig(
            "append requires --input <data.csv> or --synthetic".into(),
        ));
    };
    println!(
        "append: committed segment {} — {} rows ({} events); merged view now {} rows \
         across {} segment(s)",
        summary.seq, summary.n, summary.n_events, summary.total_rows, summary.segments
    );
    if args.flag("compact") {
        let merged = live::compact(store, 0)?;
        println!(
            "compact: merged into one store — n={} ({} chunks, {:.1} MB)",
            merged.n,
            merged.n_chunks,
            merged.bytes as f64 / 1e6
        );
    }
    Ok(())
}

/// The `watch` subcommand: poll the store fingerprint and, on growth
/// (or immediately with `--once`), warm-refit + validate + publish
/// through the gated [`Watcher`] cycle. `--reload <addr>` POSTs
/// `/v1/reload` to a running scoring server after each publish.
fn cmd_watch(args: &Args) -> Result<()> {
    let store = args.get("store").ok_or_else(|| {
        FastSurvivalError::InvalidConfig("watch requires --store <file.fsds>".into())
    })?;
    let default_name = Path::new(store)
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "model".to_string());
    let name = args.str_or("name", &default_name);
    let mut watcher = Watcher::new(store, args.str_or("models", "artifacts/serving"), &name);
    watcher.objective = Objective {
        l1: args.get_or("l1", 0.0),
        l2: args.get_or("l2", 1.0),
    };
    watcher.surrogate = match args.str_or("method", "quadratic").as_str() {
        "quadratic" => SurrogateKind::Quadratic,
        "cubic" => SurrogateKind::Cubic,
        other => {
            return Err(FastSurvivalError::Unknown {
                kind: "surrogate",
                name: other.to_string(),
                expected: "quadratic|cubic",
            })
        }
    };
    watcher.stop_kkt = args.get_or("stop-kkt", 1e-9);
    watcher.compute = compute_from_args(args)?;
    watcher.holdout_frac = args.get_or("holdout-frac", 0.1);
    watcher.holdout_seed = args.get_or("holdout-seed", 17);
    watcher.seed = args.get_or("seed", 0);
    let poll = Duration::from_secs_f64(args.get_or("poll-secs", 2.0).max(0.01));
    let max_cycles = args.get_or("max-cycles", 0usize); // 0 = forever
    let reload_addr = args.get("reload").map(|a| a.to_string());
    println!(
        "watch: {} -> {} as {name} (holdout {:.0}%, poll {:.1}s)",
        store,
        watcher.artifacts.display(),
        watcher.holdout_frac * 100.0,
        poll.as_secs_f64()
    );

    let mut last: Option<live::StoreFingerprint> = None;
    let mut cycles = 0usize;
    loop {
        let fp = live::fingerprint(Path::new(store))?;
        if last.as_ref() != Some(&fp) {
            let report = watcher.run_cycle()?;
            println!(
                "watch: cycle {} — {} ({} sweeps in {:.2}s; holdout C-index {:.4})",
                cycles + 1,
                report.reason,
                report.sweeps,
                report.refit_secs,
                report.candidate.cindex
            );
            if report.published.is_some() {
                if let Some(addr) = &reload_addr {
                    let ok = addr
                        .parse()
                        .ok()
                        .and_then(|a| HttpClient::connect(a).ok())
                        .and_then(|mut c| c.post("/v1/reload", "{}").ok())
                        .map(|r| r.status == 200)
                        .unwrap_or(false);
                    println!("watch: reload {addr} {}", if ok { "OK" } else { "FAILED" });
                }
            }
            last = Some(fp);
            cycles += 1;
            if args.flag("once") || (max_cycles > 0 && cycles >= max_cycles) {
                return Ok(());
            }
        } else if args.flag("once") {
            return Ok(());
        }
        std::thread::sleep(poll);
    }
}

const USAGE: &str = "fastsurvival — FastSurvival (NeurIPS 2024) reproduction\n\n\
usage: fastsurvival <subcommand> [--options]\n\n\
subcommands:\n\
  fit          train a CPH model (--dataset|--csv|--store --method --engine --l1 --l2 --save)\n\
  path         solution paths: λ grid or k = 1..K (--kind --lambdas --k --cv)\n\
  select       cardinality-constrained variable selection (--method --k)\n\
  experiment   regenerate a paper table/figure (--id --scale)\n\
  datasets     list datasets (Table 1 view)\n\
  convert      CSV or synthetic stream → .fsds store (--input|--synthetic --out --precision f64|f32 --shards N)\n\
  bigfit       out-of-core workload + RSS/parity/shard gates → BENCH_bigfit.json (--quick --shards --shard-workers)\n\
  bench        fixed-seed hot-path benchmarks → BENCH_optim.json (--quick --check --backend)\n\
  profile      phase table from a training trace, or per-endpoint stage table\n\
               from a serve access log / /debug/trace dump (--trace FILE)\n\
  serve        HTTP scoring server (--models --addr --workers --max-secs\n\
               --access-log FILE --slow-ms N --recorder-capacity N --request-obs)\n\
  score        batch CSV scoring (--model --input --output --horizons --chunk)\n\
  serve-smoke  off/on serving burst + parity/overhead/reconciliation gates →\n\
               BENCH_serve.json (--obs-reps --slow-ms --access-log --trace-dump --check)\n\
  append       rows → committed live segment (--store --input|--synthetic --compact)\n\
  inspect      dump + verify a store or shard set (--store file.fsds|file.fsds.shards.json)\n\
  watch        online loop (--store --models --name --once --poll-secs --reload)\n\
  live-smoke   online-loop gates: ≥3× warm refit, ≤1e-8 parity → BENCH_live.json\n\n\
compute options (fit, path, bigfit, watch, bench):\n\
  --backend auto|scalar|simd   derivative kernel backend (default auto = simd)\n\
  --threads N                  worker threads (default: FASTSURVIVAL_THREADS or cores)\n\
  --precision f64|f32          feature-cell storage; f32 halves bandwidth, f64 accumulation\n\
  --block-rows N               fixed cache-block row tile (default: auto-sized)\n\n\
observability (fit, path, bigfit, watch):\n\
  --trace-out FILE             arm span tracing, write an aggregate JSONL trace on exit;\n\
                               read it back with `fastsurvival profile --trace FILE`\n\n\
request observability (serve):\n\
  --access-log FILE            structured JSONL access log, one line per request\n\
  --slow-ms N                  pin requests slower than N ms into the slow ring\n\
  --request-obs                enable recording without an access log\n\
                               (flight recorder + sliced metrics + /debug/trace)\n\n\
see README.md for endpoint schemas and examples";

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("fit") => with_trace("fit", &args, cmd_fit),
        Some("path") => with_trace("path", &args, cmd_path),
        Some("select") => cmd_select(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("datasets") => cmd_datasets(&args),
        Some("convert") => cmd_convert(&args),
        Some("bigfit") => {
            with_trace("bigfit", &args, fastsurvival::coordinator::bigfit::run)
        }
        Some("bench") => fastsurvival::coordinator::perf::run(&args),
        Some("profile") => fastsurvival::coordinator::profile::run(&args),
        Some("serve") => cmd_serve(&args),
        Some("score") => cmd_score(&args),
        Some("serve-smoke") => smoke::run(&args),
        Some("append") => cmd_append(&args),
        Some("inspect") => fastsurvival::coordinator::inspect::run(&args),
        Some("watch") => with_trace("watch", &args, cmd_watch),
        Some("live-smoke") => live::smoke::run(&args),
        // `--help` never lands in positional (Args routes "--" tokens
        // to flags), so bare invocation or the flag both reach None.
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(FastSurvivalError::Unknown {
            kind: "subcommand",
            name: other.to_string(),
            expected: "fit|path|select|experiment|datasets|convert|bigfit|bench|profile|serve|\
                       score|serve-smoke|append|inspect|watch|live-smoke",
        }),
    }
}
