"""AOT lowering: JAX entry points -> HLO *text* artifacts for Rust.

HLO text (NOT ``lowered.serialize()``): jax >= 0.5 emits HloModuleProto
with 64-bit instruction ids which the xla crate's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids, so
text round-trips cleanly. See /opt/xla-example/README.md.

Artifacts land in ``artifacts/`` together with ``manifest.tsv``
(tab-separated: name, file, n, p, comma-joined input dtypes) which
``rust/src/runtime/artifacts.rs`` parses — no serde needed on either side.

Run as ``python -m compile.aot --out-dir ../artifacts`` from ``python/``
(what ``make artifacts`` does). Python never runs at request time.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Shape buckets the Rust runtime pads into. Per-coordinate entries exist
# for each n; the batched screening entry for (n, p) pairs.
N_BUCKETS = (1024, 4096, 16384)
NP_BUCKETS = ((1024, 128), (4096, 512))


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def entry_points():
    """(name, fn, example_args, n, p) for every artifact."""
    out = []
    for n in N_BUCKETS:
        out.append(
            (f"coord_derivs_n{n}", model.coord_derivs,
             (f32(n), f32(n), f32(n), i32(n)), n, 1)
        )
        out.append(
            (f"cox_loss_n{n}", model.cox_loss,
             (f32(n), f32(n), f32(n), i32(n)), n, 1)
        )
        out.append(
            (f"lipschitz_n{n}", model.lipschitz_constants,
             (f32(n), f32(n), i32(n), f32(n)), n, 1)
        )
    for n, p in NP_BUCKETS:
        out.append(
            (f"all_derivs_n{n}_p{p}", model.all_coord_d1_d2,
             (f32(n), f32(n, p), f32(n), i32(n)), n, p)
        )
    return out


def build(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    for name, fn, args, n, p in entry_points():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        dtypes = ",".join(
            f"{a.dtype}:{'x'.join(str(d) for d in a.shape)}" for a in args
        )
        manifest_lines.append(f"{name}\t{fname}\t{n}\t{p}\t{dtypes}")
        print(f"lowered {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(manifest_lines)} artifacts to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build(args.out_dir)


if __name__ == "__main__":
    main()
