"""Pure-jnp oracle for the Pallas kernel — the correctness reference.

Everything here is the straightforward vectorized formulation; pytest
asserts the Pallas kernel matches it over shape/dtype sweeps.
"""

import jax.numpy as jnp


def risk_set_moments_ref(w, x):
    """Reference cumulative moment sums (S0..S3)."""
    m1 = w * x
    m2 = m1 * x
    m3 = m2 * x
    return (
        jnp.cumsum(w),
        jnp.cumsum(m1),
        jnp.cumsum(m2),
        jnp.cumsum(m3),
    )


def coord_derivs_ref(w, x, delta, tie_end):
    """Reference (d1, d2, d3) per Theorem 3.1, given sorted inputs.

    Args:
      w: (n,) hazard weights exp(eta - shift); padding = 0.
      x: (n,) feature column.
      delta: (n,) event indicators (0/1 floats); padding = 0.
      tie_end: (n,) int32, index of the last member of each sample's tie
        group (risk set = prefix 0..tie_end inclusive).
    """
    s0, s1, s2, s3 = risk_set_moments_ref(w, x)
    g0 = jnp.take(s0, tie_end)
    g1 = jnp.take(s1, tie_end)
    g2 = jnp.take(s2, tie_end)
    g3 = jnp.take(s3, tie_end)
    safe = jnp.where(g0 > 0, g0, 1.0)
    m1 = g1 / safe
    m2 = g2 / safe
    m3 = g3 / safe
    d1 = jnp.sum(delta * m1) - jnp.sum(delta * x)
    d2 = jnp.sum(delta * (m2 - m1 * m1))
    d3 = jnp.sum(delta * (m3 + 2.0 * m1**3 - 3.0 * m2 * m1))
    return d1, d2, d3


def cox_loss_ref(w, v, delta, tie_end):
    """Reference negative log partial likelihood (Eq. 4), Breslow ties.

    Shift-free formulation: with w = exp(eta - shift) and v = eta - shift,
    every event contributes log(S0_w) - v, and the shift cancels exactly:
    log(sum e^eta) - eta = log(sum w) - v.
    """
    s0 = jnp.cumsum(w)
    g0 = jnp.take(s0, tie_end)
    safe = jnp.where(g0 > 0, g0, 1.0)
    terms = delta * (jnp.log(safe) - v)
    return jnp.sum(jnp.where(delta > 0, terms, 0.0))
