"""Layer-1 Pallas kernel: blocked risk-set cumulative moments.

The computational heart of FastSurvival (Corollary 3.3): with samples
sorted by descending observation time, every risk set is a prefix, so the
weighted power sums

    S_r(i) = sum_{k <= i} w_k * x_k^r,   r = 0..3,  w_k = exp(eta_k - max)

are forward cumulative sums. This kernel streams `(w, x)` through VMEM in
blocks of ``BLOCK`` elements, computes the four moment streams in one
pass, and carries the running totals across grid steps in scratch memory
— the TPU-style prefix-scan schedule (sequential grid, one carry).

``interpret=True`` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. Numerics are
validated against the pure-jnp oracle in ``ref.py`` by pytest.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Block length for the HBM->VMEM pipeline. 4 streams x BLOCK x 4 B =
# 32 KiB of VMEM at 2048 — far below the ~16 MiB budget, leaving room
# for double buffering.
BLOCK = 256


def _moments_kernel(w_ref, x_ref, s0_ref, s1_ref, s2_ref, s3_ref, carry):
    """One grid step: blockwise cumsum of w, wx, wx^2, wx^3 plus carry."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry[...] = jnp.zeros_like(carry)

    w = w_ref[...]
    x = x_ref[...]
    m0 = w
    m1 = w * x
    m2 = m1 * x
    m3 = m2 * x

    c0 = jnp.cumsum(m0)
    c1 = jnp.cumsum(m1)
    c2 = jnp.cumsum(m2)
    c3 = jnp.cumsum(m3)

    s0_ref[...] = c0 + carry[0]
    s1_ref[...] = c1 + carry[1]
    s2_ref[...] = c2 + carry[2]
    s3_ref[...] = c3 + carry[3]

    carry[0] = carry[0] + c0[-1]
    carry[1] = carry[1] + c1[-1]
    carry[2] = carry[2] + c2[-1]
    carry[3] = carry[3] + c3[-1]


def risk_set_moments(w, x, *, block=BLOCK, interpret=True):
    """Cumulative moment sums (S0, S1, S2, S3) of one feature column.

    Args:
      w: (n,) nonnegative hazard weights exp(eta - shift), descending-time
         order. Padding entries must be 0.
      x: (n,) feature column in the same order.
      block: VMEM block length; must divide n.
      interpret: run the Pallas interpreter (required on CPU).

    Returns:
      Tuple of four (n,) arrays: prefix sums of w, w*x, w*x^2, w*x^3.
    """
    n = w.shape[0]
    block = min(block, n)  # small problems: single block
    if n % block != 0:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    out = jax.ShapeDtypeStruct((n,), w.dtype)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _moments_kernel,
        grid=(n // block,),
        in_specs=[spec, spec],
        out_specs=(spec, spec, spec, spec),
        out_shape=(out, out, out, out),
        scratch_shapes=[pltpu.VMEM((4,), w.dtype)],
        interpret=interpret,
    )(w, x)
