"""Layer-2 JAX compute graphs for FastSurvival.

Entry points consumed by the Rust coordinator after AOT lowering
(``aot.py``). Conventions shared with ``rust/src/runtime``:

* Samples arrive sorted by **descending** observation time, so every risk
  set is a prefix. Padding rows go at the end with ``w = 0, delta = 0``
  and contribute nothing.
* ``w`` is the stabilized hazard weight ``exp(eta - shift)`` and ``v`` is
  ``eta - shift``; ratios and the loss are shift-invariant (see ref.py).
* ``tie_end[i]`` is the index of the last member of i's tie group —
  Breslow handling of tied times; for padding rows use index n-1.

The per-coordinate path routes its cumulative sums through the Layer-1
Pallas kernel (``kernels.cox_cumsum``), so the kernel lowers into the
same HLO artifact the coordinator executes.
"""

import jax
import jax.numpy as jnp

from .kernels.cox_cumsum import risk_set_moments


def coord_derivs(w, x, delta, tie_end):
    """Exact (d1, d2, d3) at one coordinate (Theorem 3.1), O(n).

    Returns a 3-vector [d1, d2, d3]; d1 already includes the constant
    -(X^T delta)_l term.
    """
    s0, s1, s2, s3 = risk_set_moments(w, x)
    g0 = jnp.take(s0, tie_end)
    g1 = jnp.take(s1, tie_end)
    g2 = jnp.take(s2, tie_end)
    g3 = jnp.take(s3, tie_end)
    safe = jnp.where(g0 > 0, g0, 1.0)
    m1 = g1 / safe
    m2 = g2 / safe
    m3 = g3 / safe
    d1 = jnp.sum(delta * m1) - jnp.sum(delta * x)
    d2 = jnp.sum(delta * (m2 - m1 * m1))
    d3 = jnp.sum(delta * (m3 + 2.0 * m1**3 - 3.0 * m2 * m1))
    return jnp.stack([d1, d2, d3])


def cox_loss(w, v, delta, tie_end):
    """Negative log partial likelihood (Eq. 4), shift-free formulation.

    Uses the Pallas kernel's S0 stream (x = 0 keeps the other streams
    trivially zero but shares the artifact's code path).
    """
    s0, _, _, _ = risk_set_moments(w, jnp.zeros_like(w))
    g0 = jnp.take(s0, tie_end)
    safe = jnp.where(g0 > 0, g0, 1.0)
    terms = delta * (jnp.log(safe) - v)
    return jnp.sum(jnp.where(delta > 0, terms, 0.0))


def all_coord_d1_d2(w, x_mat, delta, tie_end):
    """Batched (d1[p], d2[p]) over all coordinates — beam-search screening.

    ``x_mat`` is (n, p). Cumulative sums run along the sample axis; this
    is the vectorized Layer-2 formulation (the Pallas kernel covers the
    single-column hot path; XLA fuses this batched variant itself).
    """
    wx = w[:, None] * x_mat
    wxx = wx * x_mat
    s0 = jnp.cumsum(w)
    s1 = jnp.cumsum(wx, axis=0)
    s2 = jnp.cumsum(wxx, axis=0)
    g0 = jnp.take(s0, tie_end)
    safe = jnp.where(g0 > 0, g0, 1.0)[:, None]
    m1 = jnp.take(s1, tie_end, axis=0) / safe
    m2 = jnp.take(s2, tie_end, axis=0) / safe
    d = delta[:, None]
    d1 = jnp.sum(d * m1, axis=0) - x_mat.T @ delta
    d2 = jnp.sum(d * (m2 - m1 * m1), axis=0)
    return d1, d2


def lipschitz_constants(x, delta, tie_end, valid):
    """(L2, L3) for one coordinate (Theorem 3.4).

    Running prefix extrema of the column gathered at tie-group ends;
    ``valid`` masks padding rows out of the extrema (0/1 floats).
    """
    big = jnp.asarray(1e30, x.dtype)
    hi = jax.lax.cummax(jnp.where(valid > 0, x, -big))
    lo = jax.lax.cummin(jnp.where(valid > 0, x, big))
    rng = jnp.take(hi, tie_end) - jnp.take(lo, tie_end)
    rng = jnp.maximum(rng, 0.0)
    l2 = 0.25 * jnp.sum(delta * rng * rng)
    l3 = jnp.sum(delta * rng**3) / (6.0 * jnp.sqrt(3.0))
    return jnp.stack([l2, l3])
