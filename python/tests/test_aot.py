"""AOT lowering sanity: every entry point lowers to parseable HLO text
with the expected parameter signature, and the manifest is well-formed."""

import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def small_artifacts(tmp_path_factory, monkeypatch_module=None):
    # Lower a reduced bucket set to keep the test fast.
    out = tmp_path_factory.mktemp("artifacts")
    orig_n, orig_np = aot.N_BUCKETS, aot.NP_BUCKETS
    aot.N_BUCKETS = (256,)
    aot.NP_BUCKETS = ((256, 16),)
    try:
        aot.build(str(out))
    finally:
        aot.N_BUCKETS, aot.NP_BUCKETS = orig_n, orig_np
    return out


def test_artifacts_written(small_artifacts):
    files = sorted(os.listdir(small_artifacts))
    assert "manifest.tsv" in files
    hlo = [f for f in files if f.endswith(".hlo.txt")]
    assert len(hlo) == 4  # coord_derivs, cox_loss, lipschitz, all_derivs


def test_hlo_text_parseable_header(small_artifacts):
    for f in os.listdir(small_artifacts):
        if not f.endswith(".hlo.txt"):
            continue
        text = open(os.path.join(small_artifacts, f)).read()
        assert text.startswith("HloModule"), f"{f} missing HloModule header"
        assert "ENTRY" in text
        # The 64-bit-id proto problem does not apply to text, but make
        # sure we did not accidentally serialize a proto.
        assert "\x00" not in text


def test_manifest_schema(small_artifacts):
    lines = open(os.path.join(small_artifacts, "manifest.tsv")).read().strip().splitlines()
    assert len(lines) == 4
    for line in lines:
        name, fname, n, p, dtypes = line.split("\t")
        assert os.path.exists(os.path.join(small_artifacts, fname))
        assert int(n) > 0 and int(p) > 0
        assert all(":" in d for d in dtypes.split(","))


def test_entry_points_cover_buckets():
    eps = aot.entry_points()
    names = [e[0] for e in eps]
    for n in aot.N_BUCKETS:
        assert f"coord_derivs_n{n}" in names
        assert f"cox_loss_n{n}" in names
        assert f"lipschitz_n{n}" in names
    for n, p in aot.NP_BUCKETS:
        assert f"all_derivs_n{n}_p{p}" in names
