"""Layer-2 graph correctness: analytic derivatives vs jax.grad, loss vs a
naive python implementation, batched-vs-single parity, padding
invariance, and Lipschitz-bound checks (Theorem 3.4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import coord_derivs_ref, cox_loss_ref

jax.config.update("jax_enable_x64", True)


def make_problem(n_valid, n_pad, seed, ties=False):
    """Sorted (descending time) problem with trailing padding rows."""
    rng = np.random.default_rng(seed)
    t = rng.uniform(0.5, 9.5, size=n_valid)
    if ties:
        t = np.round(t * 2.0) / 2.0
    order = np.argsort(-t, kind="stable")
    t = t[order]
    delta = (rng.uniform(size=n_valid) < 0.7).astype(np.float64)
    if delta.sum() == 0:
        delta[0] = 1.0
    x = rng.normal(size=n_valid)
    eta = rng.normal(size=n_valid) * 0.5

    n = n_valid + n_pad
    # tie_end: last index with equal time.
    tie_end = np.zeros(n, np.int32)
    i = 0
    while i < n_valid:
        j = i
        while j + 1 < n_valid and t[j + 1] == t[i]:
            j += 1
        tie_end[i:j + 1] = j
        i = j + 1
    tie_end[n_valid:] = n - 1

    pad = lambda a, fill: np.concatenate([a, np.full(n_pad, fill, a.dtype)])
    return {
        "eta": pad(eta, -1e30),
        "x": pad(x, 0.0),
        "delta": pad(delta, 0.0),
        "tie_end": tie_end,
        "valid": pad(np.ones(n_valid), 0.0),
        "n_valid": n_valid,
    }


def wv(eta):
    shift = float(np.max(eta[np.isfinite(eta) & (eta > -1e29)]))
    w = np.exp(np.clip(eta - shift, -700, 50))
    v = np.where(eta < -1e29, 0.0, eta - shift)
    # padding: w exactly 0
    w = np.where(eta < -1e29, 0.0, w)
    return jnp.asarray(w), jnp.asarray(v)


@settings(max_examples=20, deadline=None)
@given(
    n_valid=st.integers(min_value=5, max_value=60),
    n_pad=st.sampled_from([0, 7, 30]),
    seed=st.integers(min_value=0, max_value=10_000),
    ties=st.booleans(),
)
def test_coord_derivs_match_jax_grad(n_valid, n_pad, seed, ties):
    pr = make_problem(n_valid, n_pad, seed, ties)
    x = jnp.asarray(pr["x"])
    delta = jnp.asarray(pr["delta"])
    tie_end = jnp.asarray(pr["tie_end"])
    eta = jnp.asarray(pr["eta"])

    def loss_of_beta(b):
        e = eta + b * x
        w = jnp.where(e < -1e29, 0.0, jnp.exp(e - 0.0))
        v = jnp.where(e < -1e29, 0.0, e)
        return cox_loss_ref(w, v, delta, tie_end)

    d1_auto = jax.grad(loss_of_beta)(0.0)
    d2_auto = jax.grad(jax.grad(loss_of_beta))(0.0)
    d3_auto = jax.grad(jax.grad(jax.grad(loss_of_beta)))(0.0)

    w, _ = wv(pr["eta"])
    d1, d2, d3 = coord_derivs_ref(w, x, delta, tie_end)
    np.testing.assert_allclose(float(d1), float(d1_auto), rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(float(d2), float(d2_auto), rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(float(d3), float(d3_auto), rtol=1e-5, atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(
    n_valid=st.integers(min_value=5, max_value=40),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_padding_invariance(n_valid, seed):
    a = make_problem(n_valid, 0, seed)
    b = make_problem(n_valid, 24, seed)
    wa, va = wv(a["eta"])
    wb, vb = wv(b["eta"])
    la = cox_loss_ref(wa, va, jnp.asarray(a["delta"]), jnp.asarray(a["tie_end"]))
    lb = cox_loss_ref(wb, vb, jnp.asarray(b["delta"]), jnp.asarray(b["tie_end"]))
    np.testing.assert_allclose(float(la), float(lb), rtol=1e-12)

    da = coord_derivs_ref(wa, jnp.asarray(a["x"]), jnp.asarray(a["delta"]), jnp.asarray(a["tie_end"]))
    db = coord_derivs_ref(wb, jnp.asarray(b["x"]), jnp.asarray(b["delta"]), jnp.asarray(b["tie_end"]))
    for ga, gb in zip(da, db):
        np.testing.assert_allclose(float(ga), float(gb), rtol=1e-12)


@settings(max_examples=15, deadline=None)
@given(
    n_valid=st.integers(min_value=5, max_value=40),
    p=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_all_derivs_match_single(n_valid, p, seed):
    pr = make_problem(n_valid, 8, seed)
    rng = np.random.default_rng(seed + 1)
    n = len(pr["eta"])
    x_mat = rng.normal(size=(n, p))
    x_mat[pr["valid"] == 0.0, :] = 0.0
    w, _ = wv(pr["eta"])
    delta = jnp.asarray(pr["delta"])
    tie_end = jnp.asarray(pr["tie_end"])
    d1b, d2b = model.all_coord_d1_d2(w, jnp.asarray(x_mat), delta, tie_end)
    for l in range(p):
        d1, d2, _ = coord_derivs_ref(w, jnp.asarray(x_mat[:, l]), delta, tie_end)
        np.testing.assert_allclose(float(d1b[l]), float(d1), rtol=1e-9, atol=1e-10)
        np.testing.assert_allclose(float(d2b[l]), float(d2), rtol=1e-9, atol=1e-10)


@settings(max_examples=15, deadline=None)
@given(
    n_valid=st.integers(min_value=5, max_value=40),
    seed=st.integers(min_value=0, max_value=10_000),
    scale=st.floats(min_value=0.0, max_value=2.0),
)
def test_lipschitz_bounds_hold(n_valid, seed, scale):
    pr = make_problem(n_valid, 8, seed)
    x = jnp.asarray(pr["x"])
    delta = jnp.asarray(pr["delta"])
    tie_end = jnp.asarray(pr["tie_end"])
    valid = jnp.asarray(pr["valid"])
    l2, l3 = model.lipschitz_constants(x, delta, tie_end, valid)
    # derivatives at a random beta along this coordinate
    eta = np.where(pr["eta"] < -1e29, -1e30, pr["eta"] + scale * np.asarray(x))
    w, _ = wv(eta)
    _, d2, d3 = coord_derivs_ref(w, x, delta, tie_end)
    assert float(d2) <= float(l2) + 1e-6
    assert abs(float(d3)) <= float(l3) + 1e-6
    assert float(d2) >= -1e-9


def test_pallas_coord_derivs_matches_ref_f32():
    # The Layer-2 entry (through the Pallas kernel) against the oracle.
    pr = make_problem(200, 56, 3)
    w, _ = wv(pr["eta"])
    w32 = jnp.asarray(np.asarray(w), jnp.float32)
    x32 = jnp.asarray(pr["x"], jnp.float32)
    d32 = jnp.asarray(pr["delta"], jnp.float32)
    te = jnp.asarray(pr["tie_end"])
    got = model.coord_derivs(w32, x32, d32, te)
    want = coord_derivs_ref(w, jnp.asarray(pr["x"]), jnp.asarray(pr["delta"]), te)
    for g, r in zip(np.asarray(got), want):
        np.testing.assert_allclose(float(g), float(r), rtol=5e-4, atol=5e-4)


def test_cox_loss_entry_matches_ref():
    pr = make_problem(128, 0, 9)
    w, v = wv(pr["eta"])
    w32 = jnp.asarray(np.asarray(w), jnp.float32)
    v32 = jnp.asarray(np.asarray(v), jnp.float32)
    d32 = jnp.asarray(pr["delta"], jnp.float32)
    te = jnp.asarray(pr["tie_end"])
    got = model.cox_loss(w32, v32, d32, te)
    want = cox_loss_ref(w, v, jnp.asarray(pr["delta"]), te)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
