"""Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes and value ranges; each case asserts the blocked
scan matches jnp.cumsum to float32 tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.cox_cumsum import risk_set_moments
from compile.kernels.ref import risk_set_moments_ref


def _compare(w, x, block):
    got = risk_set_moments(jnp.asarray(w), jnp.asarray(x), block=block)
    want = risk_set_moments_ref(jnp.asarray(w), jnp.asarray(x))
    for g, r, name in zip(got, want, ["s0", "s1", "s2", "s3"]):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(r), rtol=2e-5, atol=2e-5,
            err_msg=f"stream {name}")


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=6),
    block=st.sampled_from([8, 32, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_random(blocks, block, seed):
    rng = np.random.default_rng(seed)
    n = blocks * block
    w = rng.exponential(size=n).astype(np.float32)
    x = rng.normal(size=n).astype(np.float32)
    _compare(w, x, block)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_kernel_with_zero_padding_tail(seed):
    # Padding convention: trailing w=0 rows leave all streams constant.
    rng = np.random.default_rng(seed)
    n, valid = 256, 100
    w = np.zeros(n, np.float32)
    w[:valid] = rng.exponential(size=valid)
    x = rng.normal(size=n).astype(np.float32)
    s0, s1, s2, s3 = risk_set_moments(jnp.asarray(w), jnp.asarray(x), block=64)
    for s in (s0, s1, s2, s3):
        tail = np.asarray(s)[valid:]
        assert np.allclose(tail, tail[0]), "padding must not move the sums"


def test_kernel_single_block():
    w = np.ones(32, np.float32)
    x = np.arange(32, dtype=np.float32)
    _compare(w, x, 32)


def test_kernel_rejects_indivisible_n():
    with pytest.raises(ValueError):
        risk_set_moments(jnp.ones(100), jnp.ones(100), block=64)


def test_kernel_many_blocks_carry_exact():
    # Constant w=1 makes S0 = arange+1 exactly; checks the carry chain.
    n, block = 1024, 128
    w = np.ones(n, np.float32)
    x = np.ones(n, np.float32)
    s0, s1, _, _ = risk_set_moments(jnp.asarray(w), jnp.asarray(x), block=block)
    np.testing.assert_array_equal(np.asarray(s0), np.arange(1, n + 1, dtype=np.float32))
    np.testing.assert_array_equal(np.asarray(s1), np.arange(1, n + 1, dtype=np.float32))
