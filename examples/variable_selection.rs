//! Variable selection under extreme correlation (the Figure-2 workload):
//! beam search vs ABESS vs Coxnet vs Adaptive Lasso on AR(1) ρ=0.9
//! synthetic data with a planted 15-feature support.
//!
//! Run with: `cargo run --release --example variable_selection`

use fastsurvival::cox::CoxProblem;
use fastsurvival::data::synthetic::{generate, SyntheticConfig};
use fastsurvival::metrics::support_f1;
use fastsurvival::select::{Abess, AdaptiveLasso, BeamSearch, CoxnetPath, VariableSelector};

fn main() {
    let ds = generate(&SyntheticConfig {
        n: 1200,
        p: 1200,
        rho: 0.9,
        k: 15,
        s: 0.1,
        seed: 0,
    });
    let truth = ds.true_beta.clone().unwrap();
    println!(
        "synthetic high-correlation dataset (paper Fig. 2, leftmost): n={} p={} true support 15, rho=0.9",
        ds.n(),
        ds.p()
    );
    let problem = CoxProblem::new(&ds);

    let selectors: Vec<Box<dyn VariableSelector>> = vec![
        Box::new(BeamSearch { width: 8, screen: 20, ..Default::default() }),
        Box::new(Abess::default()),
        Box::new(CoxnetPath { n_lambdas: 30, ..Default::default() }),
        Box::new(AdaptiveLasso::default()),
    ];

    println!("\n{:<22} {:>4} {:>10} {:>8} {:>8} {:>8}", "method", "k", "loss", "P", "R", "F1");
    for sel in &selectors {
        let sols = sel.select(&problem, &[15]);
        for sol in sols {
            let s = support_f1(&truth, &sol.beta, 1e-10);
            println!(
                "{:<22} {:>4} {:>10.3} {:>8.3} {:>8.3} {:>8.3}",
                sel.name(),
                sol.k,
                sol.train_loss,
                s.precision,
                s.recall,
                s.f1
            );
        }
    }
    println!(
        "\nThe beam search (ours) should dominate the F1 column — the paper's\n\
         headline variable-selection result (Figure 2)."
    );
}
