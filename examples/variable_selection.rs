//! Variable selection under extreme correlation (the Figure-2 workload):
//! beam search vs ABESS vs Coxnet vs Adaptive Lasso on AR(1) ρ=0.9
//! synthetic data with a planted 15-feature support, followed by a
//! refit of the best support through the unified `CoxFit` API.
//!
//! Run with: `cargo run --release --example variable_selection`

use fastsurvival::api::CoxFit;
use fastsurvival::cox::CoxProblem;
use fastsurvival::data::synthetic::{generate, SyntheticConfig};
use fastsurvival::error::Result;
use fastsurvival::metrics::support_f1;
use fastsurvival::select::{Abess, AdaptiveLasso, BeamSearch, CoxnetPath, VariableSelector};

fn main() -> Result<()> {
    let ds = generate(&SyntheticConfig {
        n: 1200,
        p: 1200,
        rho: 0.9,
        k: 15,
        s: 0.1,
        seed: 0,
    });
    let truth = ds.true_beta.clone().unwrap();
    println!(
        "synthetic high-correlation dataset (paper Fig. 2, leftmost): n={} p={} true support 15, rho=0.9",
        ds.n(),
        ds.p()
    );
    let problem = CoxProblem::try_new(&ds)?;

    let selectors: Vec<Box<dyn VariableSelector>> = vec![
        Box::new(BeamSearch { width: 8, screen: 20, ..Default::default() }),
        Box::new(Abess::default()),
        Box::new(CoxnetPath { n_lambdas: 30, ..Default::default() }),
        Box::new(AdaptiveLasso::default()),
    ];

    let mut best: Option<(f64, Vec<usize>)> = None;
    println!("\n{:<22} {:>4} {:>10} {:>8} {:>8} {:>8}", "method", "k", "loss", "P", "R", "F1");
    for sel in &selectors {
        let sols = sel.select(&problem, &[15]);
        for sol in sols {
            let s = support_f1(&truth, &sol.beta, 1e-10);
            println!(
                "{:<22} {:>4} {:>10.3} {:>8.3} {:>8.3} {:>8.3}",
                sel.name(),
                sol.k,
                sol.train_loss,
                s.precision,
                s.recall,
                s.f1
            );
            let support: Vec<usize> = sol
                .beta
                .iter()
                .enumerate()
                .filter(|(_, b)| b.abs() > 1e-10)
                .map(|(j, _)| j)
                .collect();
            if best.as_ref().map(|(f, _)| s.f1 > *f).unwrap_or(true) {
                best = Some((s.f1, support));
            }
        }
    }
    println!(
        "\nThe beam search (ours) should dominate the F1 column — the paper's\n\
         headline variable-selection result (Figure 2)."
    );

    // Refit the winning support through the unified estimator API: the
    // selector chooses the variables, `CoxFit` owns the final model.
    if let Some((f1, support)) = best {
        let sub = ds.select_features(&support);
        let model = CoxFit::new().l2(0.01).max_iters(300).tol(1e-10).fit(&sub)?;
        println!(
            "\nrefit of best support (F1 {f1:.3}, {} features) via CoxFit: \
             objective {:.3}, train CIndex {:.4}",
            support.len(),
            model.diagnostics().objective_value,
            model.concordance(&sub)?
        );
    }
    Ok(())
}
