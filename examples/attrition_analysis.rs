//! End-to-end driver (the repository's full-system workload): an
//! employee-attrition analysis exercising every layer of the stack:
//!
//!   1. dataset build + Sec-4.2 quantile binarization (highly correlated
//!      one-hot features),
//!   2. AOT artifacts loaded and executed through PJRT (`XlaEngine`) with
//!      a native-vs-XLA parity check on live data — proving the Pallas
//!      kernel (L1), the JAX graphs (L2), and this Rust coordinator (L3)
//!      compose,
//!   3. a 5-fold cross-validated sparse-model comparison (beam search vs
//!      Coxnet) with CIndex/IBS, the Figure-3 analysis.
//!
//! Run with: `make artifacts && cargo run --release --example attrition_analysis`

use fastsurvival::coordinator::cv::cv_selector;
use fastsurvival::cox::{CoxProblem, CoxState};
use fastsurvival::data::binarize::{binarize, BinarizeConfig};
use fastsurvival::data::datasets;
use fastsurvival::runtime::engine::{CoxEngine, NativeEngine, XlaEngine};
use fastsurvival::select::{BeamSearch, CoxnetPath, VariableSelector};
use fastsurvival::util::table::{fnum, Table};
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    // ---- 1. data -------------------------------------------------------
    let mut spec = datasets::spec("employee_attrition");
    spec.n = 2000; // scaled stand-in; drop data/employee_attrition.csv for real data
    let raw = datasets::generate_stand_in(&spec, 0);
    let ds = binarize(&raw, &BinarizeConfig { max_quantiles: 20, ..Default::default() });
    println!(
        "employee attrition: n={} raw p={} -> binarized p={} (censoring {:.0}%)",
        ds.n(),
        raw.p(),
        ds.p(),
        100.0 * ds.censoring_rate()
    );
    let problem = CoxProblem::new(&ds);

    // ---- 2. three-layer composition check ------------------------------
    let artifact_dir = Path::new("artifacts");
    if artifact_dir.join("manifest.tsv").exists() {
        let xla = XlaEngine::new(artifact_dir)?;
        let native = NativeEngine;
        let state = CoxState::zeros(&problem);
        let t0 = Instant::now();
        let ln = native.loss(&problem, &state)?;
        let t_native = t0.elapsed();
        let t1 = Instant::now();
        let lx = xla.loss(&problem, &state)?;
        let t_xla = t1.elapsed();
        let d_n = native.coord_derivs(&problem, &state, 0)?;
        let d_x = xla.coord_derivs(&problem, &state, 0)?;
        println!(
            "\nlayer check (PJRT platform {}):\n  loss    native {:.6} ({:?})  xla {:.6} ({:?})\n  d1[0]   native {:+.6}  xla {:+.6}",
            xla.runtime().platform(),
            ln,
            t_native,
            lx,
            t_xla,
            d_n.d1,
            d_x.d1,
        );
        assert!((ln - lx).abs() / (ln.abs() + 1.0) < 1e-4, "loss parity");
        assert!((d_n.d1 - d_x.d1).abs() < 1e-2 * (d_n.d1.abs() + 1.0), "derivative parity");
        println!("  ✓ native and AOT-XLA engines agree — all three layers compose");
    } else {
        println!("\n(artifacts/ missing — run `make artifacts` for the XLA layer check)");
    }

    // ---- 3. sparse-model comparison (Figure-3 analysis) ----------------
    let ks: Vec<usize> = (1..=8).collect();
    let selectors: Vec<Box<dyn VariableSelector>> = vec![
        Box::new(BeamSearch { width: 5, screen: 12, ..Default::default() }),
        Box::new(CoxnetPath::default()),
    ];

    let mut table = Table::new(
        "5-fold CV: sparsity vs accuracy (higher CIndex / lower IBS better)",
        &["method", "k", "test CIndex", "test IBS", "train CIndex"],
    );
    for sel in &selectors {
        let t0 = Instant::now();
        let rows = cv_selector(&ds, sel.as_ref(), &ks, 5, 0);
        println!("\n{} finished 5-fold CV in {:?}", sel.name(), t0.elapsed());
        // mean per k
        let mut by_k: BTreeMap<usize, (Vec<f64>, Vec<f64>, Vec<f64>)> = BTreeMap::new();
        for r in &rows {
            let e = by_k.entry(r.k).or_default();
            e.0.push(r.test_cindex);
            e.1.push(r.test_ibs);
            e.2.push(r.train_cindex);
        }
        for (k, (ci, ibs, tci)) in by_k {
            let mean = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
            table.row(vec![
                sel.name().to_string(),
                k.to_string(),
                fnum(mean(&ci)),
                fnum(mean(&ibs)),
                fnum(mean(&tci)),
            ]);
        }
    }
    println!("\n{}", table.render());
    table.write_csv(Path::new("results/attrition_analysis.csv"))?;
    println!("wrote results/attrition_analysis.csv");
    Ok(())
}
