//! End-to-end driver (the repository's full-system workload): an
//! employee-attrition analysis exercising every layer of the stack:
//!
//!   1. dataset build + Sec-4.2 quantile binarization (highly correlated
//!      one-hot features),
//!   2. the unified `CoxFit` path on both engines — the same builder fits
//!      through the native kernels and, when the AOT artifacts and the
//!      `xla` feature are present, through PJRT, proving the Pallas
//!      kernel (L1), the JAX graphs (L2), and this Rust coordinator (L3)
//!      compose — plus a persisted model artifact,
//!   3. a 5-fold cross-validated sparse-model comparison (beam search vs
//!      Coxnet) with CIndex/IBS, the Figure-3 analysis.
//!
//! Run with: `make artifacts && cargo run --release --example attrition_analysis`

use fastsurvival::api::{CoxFit, EngineKind};
use fastsurvival::coordinator::cv::cv_selector;
use fastsurvival::data::binarize::{binarize, BinarizeConfig};
use fastsurvival::data::datasets;
use fastsurvival::error::Result;
use fastsurvival::select::{BeamSearch, CoxnetPath, VariableSelector};
use fastsurvival::util::table::{fnum, Table};
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

fn main() -> Result<()> {
    // ---- 1. data -------------------------------------------------------
    let mut spec = datasets::spec("employee_attrition");
    spec.n = 2000; // scaled stand-in; drop data/employee_attrition.csv for real data
    let raw = datasets::generate_stand_in(&spec, 0);
    let ds = binarize(&raw, &BinarizeConfig { max_quantiles: 20, ..Default::default() });
    println!(
        "employee attrition: n={} raw p={} -> binarized p={} (censoring {:.0}%)",
        ds.n(),
        raw.p(),
        ds.p(),
        100.0 * ds.censoring_rate()
    );

    // ---- 2. one builder, both engines ----------------------------------
    let base = CoxFit::new().l1(0.5).l2(0.5).max_iters(40).tol(1e-9);
    let t0 = Instant::now();
    let native_model = base.clone().engine(EngineKind::Native).fit(&ds)?;
    println!(
        "\nnative fit: objective {:.6} in {} sweeps ({:?})",
        native_model.diagnostics().objective_value,
        native_model.diagnostics().iterations,
        t0.elapsed()
    );
    let artifact_dir = Path::new("artifacts");
    if artifact_dir.join("manifest.tsv").exists() {
        match base.clone().engine(EngineKind::Xla).fit(&ds) {
            Ok(xla_model) => {
                let (a, b) = (
                    native_model.diagnostics().objective_value,
                    xla_model.diagnostics().objective_value,
                );
                println!("xla fit:    objective {b:.6} in {} sweeps", xla_model.diagnostics().iterations);
                assert!((a - b).abs() / (a.abs() + 1.0) < 1e-3, "engine parity: {a} vs {b}");
                println!("  ✓ native and AOT-XLA engines agree — all three layers compose");
            }
            Err(e) => println!("(xla engine unavailable: {e})"),
        }
    } else {
        println!("(artifacts/ missing — run `make artifacts` for the XLA layer check)");
    }

    // Persist the fitted model like a serving job would.
    let model_path = Path::new("results/attrition_model.json");
    native_model.save(model_path)?;
    println!(
        "saved model to {} ({} nonzero of {} coefficients)",
        model_path.display(),
        native_model.nonzero_coefficients(1e-10).len(),
        native_model.p()
    );

    // ---- 3. sparse-model comparison (Figure-3 analysis) ----------------
    let ks: Vec<usize> = (1..=8).collect();
    let selectors: Vec<Box<dyn VariableSelector>> = vec![
        Box::new(BeamSearch { width: 5, screen: 12, ..Default::default() }),
        Box::new(CoxnetPath::default()),
    ];

    let mut table = Table::new(
        "5-fold CV: sparsity vs accuracy (higher CIndex / lower IBS better)",
        &["method", "k", "test CIndex", "test IBS", "train CIndex"],
    );
    for sel in &selectors {
        let t0 = Instant::now();
        let rows = cv_selector(&ds, sel.as_ref(), &ks, 5, 0);
        println!("\n{} finished 5-fold CV in {:?}", sel.name(), t0.elapsed());
        // mean per k
        let mut by_k: BTreeMap<usize, (Vec<f64>, Vec<f64>, Vec<f64>)> = BTreeMap::new();
        for r in &rows {
            let e = by_k.entry(r.k).or_default();
            e.0.push(r.test_cindex);
            e.1.push(r.test_ibs);
            e.2.push(r.train_cindex);
        }
        for (k, (ci, ibs, tci)) in by_k {
            let mean = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
            table.row(vec![
                sel.name().to_string(),
                k.to_string(),
                fnum(mean(&ci)),
                fnum(mean(&ibs)),
                fnum(mean(&tci)),
            ]);
        }
    }
    println!("\n{}", table.render());
    table
        .write_csv(Path::new("results/attrition_analysis.csv"))
        .map_err(|e| fastsurvival::error::FastSurvivalError::io("writing attrition CSV", e))?;
    println!("wrote results/attrition_analysis.csv");
    Ok(())
}
