//! Quickstart: fit a Cox proportional hazards model with FastSurvival's
//! cubic-surrogate coordinate descent and inspect the result.
//!
//! Run with: `cargo run --release --example quickstart`

use fastsurvival::cox::CoxProblem;
use fastsurvival::data::synthetic::{generate, SyntheticConfig};
use fastsurvival::metrics::concordance_index;
use fastsurvival::optim::{CubicSurrogate, FitConfig, Objective, Optimizer};

fn main() {
    // 1. A synthetic survival dataset (Appendix C.2 generator): 500
    //    samples, 20 features, 4 of which carry signal.
    let ds = generate(&SyntheticConfig {
        n: 500,
        p: 20,
        rho: 0.5,
        k: 4,
        s: 0.1,
        seed: 7,
    });
    println!(
        "dataset: n={} p={} events={} censoring={:.0}%",
        ds.n(),
        ds.p(),
        ds.n_events(),
        100.0 * ds.censoring_rate()
    );

    // 2. Preprocess: sort by descending time so risk sets are prefixes.
    let problem = CoxProblem::new(&ds);

    // 3. Fit with the cubic surrogate (guaranteed monotone descent,
    //    no line search, O(n) exact second derivatives per coordinate).
    let cfg = FitConfig {
        objective: Objective { l1: 0.5, l2: 0.1 },
        max_iters: 200,
        tol: 1e-10,
        ..Default::default()
    };
    let result = CubicSurrogate.fit(&problem, &cfg);
    println!(
        "fit: objective {:.4} in {} sweeps (monotone descent: {})",
        result.objective_value,
        result.iterations,
        result.trace.monotone(1e-9)
    );

    // 4. Inspect the model.
    let nonzero: Vec<(usize, f64)> = result
        .beta
        .iter()
        .enumerate()
        .filter(|(_, b)| b.abs() > 1e-10)
        .map(|(j, &b)| (j, b))
        .collect();
    println!("selected {} features:", nonzero.len());
    for (j, b) in &nonzero {
        let truth = ds.true_beta.as_ref().unwrap()[*j];
        println!("  x{j:<3} beta = {b:+.4}   (true {truth:+.1})");
    }

    // 5. Evaluate.
    let eta = ds.x.matvec(&result.beta);
    let ci = concordance_index(&ds.time, &ds.event, &eta);
    println!("train concordance index: {ci:.4}");
    assert!(ci > 0.7, "expected an informative model");
}
