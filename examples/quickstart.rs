//! Quickstart: the unified estimator API end to end — build a `CoxFit`,
//! fit a `CoxModel`, predict survival curves, and round-trip the model
//! through JSON persistence.
//!
//! Run with: `cargo run --release --example quickstart`

use fastsurvival::api::{CoxFit, CoxModel, EngineKind, OptimizerKind};
use fastsurvival::data::synthetic::{generate, SyntheticConfig};
use fastsurvival::error::Result;

fn main() -> Result<()> {
    // 1. A synthetic survival dataset (Appendix C.2 generator): 500
    //    samples, 20 features, 4 of which carry signal.
    let ds = generate(&SyntheticConfig {
        n: 500,
        p: 20,
        rho: 0.5,
        k: 4,
        s: 0.1,
        seed: 7,
    });
    println!(
        "dataset: n={} p={} events={} censoring={:.0}%",
        ds.n(),
        ds.p(),
        ds.n_events(),
        100.0 * ds.censoring_rate()
    );

    // 2. One builder call: penalties, optimizer, engine, stopping — the
    //    cubic surrogate gives guaranteed monotone descent with no line
    //    search and O(n) exact second derivatives per coordinate.
    let model = CoxFit::new()
        .l1(0.5)
        .l2(0.1)
        .optimizer(OptimizerKind::Cubic)
        .engine(EngineKind::Native)
        .max_iters(200)
        .tol(1e-10)
        .fit(&ds)?;
    let d = model.diagnostics();
    println!(
        "fit: objective {:.4} in {} sweeps via {} on {} (monotone descent: {})",
        d.objective_value,
        d.iterations,
        d.optimizer,
        d.engine,
        d.trace.monotone(1e-9)
    );

    // 3. Inspect the selected coefficients, keyed by feature name.
    let truth = ds.true_beta.as_ref().unwrap();
    let selected = model.nonzero_coefficients(1e-10);
    println!("selected {} features:", selected.len());
    for c in &selected {
        println!("  {:<4} beta = {:+.4}   (true {:+.1})", c.name, c.value, truth[c.index]);
    }

    // 4. Predict: risk scores and individual survival curves.
    let ci = model.concordance(&ds)?;
    println!("train concordance index: {ci:.4}");
    assert!(ci > 0.7, "expected an informative model");
    let horizons = [0.25, 0.5, 1.0, 2.0];
    let mut prev = vec![1.0; 3];
    print!("survival of first 3 subjects:");
    for &t in &horizons {
        let s = model.predict_survival(&ds.x, t)?;
        print!("  t={t}: [{:.3} {:.3} {:.3}]", s[0], s[1], s[2]);
        for i in 0..3 {
            assert!(s[i] <= prev[i] + 1e-12, "survival must be monotone in t");
            prev[i] = s[i];
        }
    }
    println!();

    // 5. Persist and reload: predictions must be bit-identical.
    let path = std::env::temp_dir().join("fastsurvival_quickstart_model.json");
    model.save(&path)?;
    let loaded = CoxModel::load(&path)?;
    let before = model.predict_survival(&ds.x, 1.0)?;
    let after = loaded.predict_survival(&ds.x, 1.0)?;
    assert_eq!(before, after, "save/load must preserve predictions exactly");
    println!("model round-tripped through {} ✓", path.display());
    Ok(())
}
