//! Whole solution families in one call: the warm-started, strong-rule
//! screened λ-path and the cardinality (k) path, plus path-based
//! cross-validation to pick the winner.
//!
//! Run with: `cargo run --release --example regularization_path`

use fastsurvival::api::CoxFit;
use fastsurvival::coordinator::cv::{cv_l1_path, SelectionCriterion};
use fastsurvival::data::synthetic::{generate, SyntheticConfig};
use fastsurvival::error::Result;
use fastsurvival::path::PathSolver;

fn main() -> Result<()> {
    // A synthetic dataset with 5 informative features among 40.
    let ds = generate(&SyntheticConfig {
        n: 800,
        p: 40,
        rho: 0.5,
        k: 5,
        s: 0.1,
        seed: 13,
    });
    println!(
        "dataset: n={} p={} events={} (5 informative features planted)",
        ds.n(),
        ds.p(),
        ds.n_events()
    );

    // 1. The λ-path: 40 grid points from λ_max (empty model) down to
    //    0.01·λ_max, each warm-started from the previous solution with
    //    sequential strong-rule screening and a full KKT check. One call,
    //    forty fitted models.
    let path = CoxFit::new().n_lambdas(40).l1_path(&ds)?;
    println!("\nλ-path: {} points in {:.1} ms", path.len(), path.wall_secs() * 1e3);
    for pt in path.points().iter().step_by(8) {
        println!(
            "  λ = {:<10.5} support = {:<3} train loss = {:.3}",
            pt.lambda.unwrap_or(0.0),
            pt.k,
            pt.train_loss
        );
    }

    // 2. Any point materializes as a full CoxModel — prediction,
    //    concordance, JSON persistence — without refitting.
    let dense = path.model_for_lambda(0.0)?; // λ_min endpoint
    println!(
        "\nλ_min model: {} nonzero coefficients, train CIndex {:.4}",
        dense.nonzero_coefficients(1e-10).len(),
        dense.concordance(&ds)?
    );

    // 3. Path-based cross-validation: one path per fold (folds run in
    //    parallel), λ chosen by out-of-fold partial-likelihood deviance.
    let solver = PathSolver { n_lambdas: 40, ..Default::default() };
    let cv = cv_l1_path(&ds, &solver, 5, 0, SelectionCriterion::Deviance)?;
    let best = cv.best();
    println!(
        "\n5-fold CV: best λ = {:.5} (mean deviance {:.2}, mean support {:.1}, \
         mean CIndex {:.4})",
        best.grid_value, best.mean_test_deviance, best.mean_support, best.mean_test_cindex
    );

    // 4. The k-path: cardinality-constrained solutions k = 1..8 from the
    //    paper's beam search, each level warm-extending the previous one.
    let kpath = CoxFit::new().cardinality_path(&ds, 8)?;
    println!("\nk-path: {} points", kpath.len());
    for pt in kpath.points() {
        println!("  k = {:<2} train loss = {:.3}", pt.k, pt.train_loss);
    }
    let sparse = kpath.model_for_k(5)?;
    println!(
        "k=5 model recovers CIndex {:.4} with 5 features",
        sparse.concordance(&ds)?
    );
    Ok(())
}
