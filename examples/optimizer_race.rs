//! The Figure-1 experiment, live: race every optimizer on a binarized
//! dataset through the one `CoxFit` builder path and watch the
//! Newton-family baselines blow up at weak regularization (surfacing as
//! a typed `Diverged` error) while the surrogate methods descend
//! monotonically.
//!
//! Run with: `cargo run --release --example optimizer_race [--dataset flchain]`

use fastsurvival::api::{CoxFit, OptimizerKind};
use fastsurvival::data::binarize::{binarize, BinarizeConfig};
use fastsurvival::data::datasets;
use fastsurvival::error::FastSurvivalError;
use fastsurvival::util::args::Args;

fn main() {
    let args = Args::from_env();
    let name = args.str_or("dataset", "flchain");
    let mut spec = datasets::spec(&name);
    spec.n = args.get_or("n", 1000);
    // quantiles=40 yields rare threshold indicators with near-zero
    // curvature at β=0 — the regime where plain Newton overshoots (Fig 1).
    let raw = datasets::generate_stand_in(&spec, args.get_or("seed", 1));
    let ds = binarize(&raw, &BinarizeConfig {
        max_quantiles: args.get_or("quantiles", 40),
        ..Default::default()
    });
    println!("{name}: n={} p={} (binarized)", ds.n(), ds.p());

    for (l1, l2) in [(0.0, 1.0), (1.0, 5.0)] {
        println!("\n=== λ1={l1} λ2={l2} ===");
        println!(
            "{:<20} {:>12} {:>8} {:>10} {:>9} {:>9}",
            "method", "final loss", "iters", "time(ms)", "monotone", "outcome"
        );
        for kind in OptimizerKind::ALL {
            if kind == OptimizerKind::NewtonLineSearch {
                continue; // the ablation; the race runs the paper's six
            }
            if l1 > 0.0 && !kind.supports_l1() {
                continue; // exact Newton has no ℓ1 mode (paper)
            }
            let fit = CoxFit::new()
                .l1(l1)
                .l2(l2)
                .optimizer(kind)
                .max_iters(args.get_or("iters", 30))
                .tol(1e-11)
                .budget_secs(30.0);
            match fit.fit(&ds) {
                Ok(model) => {
                    let d = model.diagnostics();
                    println!(
                        "{:<20} {:>12.4} {:>8} {:>10.1} {:>9} {:>9}",
                        d.optimizer,
                        d.objective_value,
                        d.iterations,
                        d.wall_secs * 1e3,
                        d.trace.monotone(1e-8),
                        if d.converged { "converged" } else { "maxiter" }
                    );
                }
                Err(FastSurvivalError::Diverged { optimizer, iterations }) => {
                    println!(
                        "{:<20} {:>12} {:>8} {:>10} {:>9} {:>9}",
                        optimizer, "-", iterations, "-", "false", "DIVERGED"
                    );
                }
                Err(e) => {
                    println!("{:<20} failed: {e}", kind.name());
                }
            }
        }
    }
    println!(
        "\nExpected shape (paper Fig. 1): surrogates always monotone and fastest\n\
         to high precision; exact Newton explodes at weak λ2 on binarized data\n\
         and surfaces as the typed Diverged error."
    );
}
