//! The Figure-1 experiment, live: race every optimizer on a binarized
//! dataset and watch the Newton-family baselines blow up at weak
//! regularization while the surrogate methods descend monotonically.
//!
//! Run with: `cargo run --release --example optimizer_race [--dataset flchain]`

use fastsurvival::cox::CoxProblem;
use fastsurvival::data::binarize::{binarize, BinarizeConfig};
use fastsurvival::data::datasets;
use fastsurvival::optim::{self, FitConfig, Objective, Optimizer};
use fastsurvival::util::args::Args;

fn main() {
    let args = Args::from_env();
    let name = args.str_or("dataset", "flchain");
    let mut spec = datasets::spec(&name);
    spec.n = args.get_or("n", 1000);
    // quantiles=40 yields rare threshold indicators with near-zero
    // curvature at β=0 — the regime where plain Newton overshoots (Fig 1).
    let raw = datasets::generate_stand_in(&spec, args.get_or("seed", 1));
    let ds = binarize(&raw, &BinarizeConfig {
        max_quantiles: args.get_or("quantiles", 40),
        ..Default::default()
    });
    let pr = CoxProblem::new(&ds);
    println!("{name}: n={} p={} (binarized)", ds.n(), ds.p());

    for (l1, l2) in [(0.0, 1.0), (1.0, 5.0)] {
        println!("\n=== λ1={l1} λ2={l2} ===");
        println!(
            "{:<20} {:>12} {:>8} {:>10} {:>9} {:>9}",
            "method", "final loss", "iters", "time(ms)", "monotone", "diverged"
        );
        let methods: &[&str] = if l1 == 0.0 {
            &["quadratic", "cubic", "newton", "quasi-newton", "prox-newton", "gd"]
        } else {
            &["quadratic", "cubic", "quasi-newton", "prox-newton", "gd"]
        };
        for m in methods {
            let opt = optim::by_name(m);
            let cfg = FitConfig {
                objective: Objective { l1, l2 },
                max_iters: args.get_or("iters", 30),
                tol: 1e-11,
                budget_secs: 30.0,
                record_trace: true,
            };
            let t0 = std::time::Instant::now();
            let res = opt.fit(&pr, &cfg);
            println!(
                "{:<20} {:>12.4} {:>8} {:>10.1} {:>9} {:>9}",
                opt.name(),
                res.objective_value,
                res.iterations,
                t0.elapsed().as_secs_f64() * 1e3,
                res.trace.monotone(1e-8),
                res.trace.diverged
            );
        }
    }
    println!(
        "\nExpected shape (paper Fig. 1): surrogates always monotone and fastest\n\
         to high precision; exact Newton explodes at weak λ2 on binarized data."
    );
}
